//! INT8 LeNet-5 (NITI): forward bit-identical to the XLA artifact
//! (`python/compile/int8_model.py`), plus integer-only tail-BP and
//! full-BP — the engine behind the paper's INT8 and INT8* columns.
//!
//! Parameter ABI (no biases, as NITI): `[conv1_w, conv2_w, fc1_w,
//! fc2_w, fc3_w]`, each a `QTensor` (int8 mantissa + exponent).

use super::layers;
use super::qtensor::{requantize, QTensor};
use super::rounding::clamp_i8;

pub const NCLASS: usize = 10;

pub const PARAM_SPECS: [(&str, &[usize]); 5] = [
    ("conv1_w", &[6, 1, 5, 5]),
    ("conv2_w", &[16, 6, 5, 5]),
    ("fc1_w", &[784, 120]),
    ("fc2_w", &[120, 84]),
    ("fc3_w", &[84, 10]),
];

/// Deepest BP tail [`tail_update`] supports: the whole FC classifier
/// stack (fc1..fc3). Matches `coordinator::engine::CLS_STACK`.
pub const MAX_BP_TAIL: usize = 3;

/// Number of weight tensors trained by ZO for a partition name.
/// (Full ZO = 5, Cls1 = 4, Cls2 = 3, bp-tail=3 = 2, Full BP = 0.)
pub fn zo_layer_count(bp_layers: usize) -> usize {
    assert!(bp_layers <= MAX_BP_TAIL, "bp tail {bp_layers} exceeds the FC stack");
    5 - bp_layers
}

/// Initialize NITI weights: uniform int8 in ±r_init, exponent −7
/// (values ∈ [−r_init/128, r_init/128] — NITI's uniform init).
pub fn init_params(seed: u64, r_init: i8) -> Vec<QTensor> {
    let mut rng = crate::rng::Rng64::new(seed);
    PARAM_SPECS
        .iter()
        .map(|(_, shape)| {
            let n: usize = shape.iter().product();
            let data = (0..n)
                .map(|_| rng.uniform_i32(-(r_init as i32), r_init as i32) as i8)
                .collect();
            QTensor::from_vec(shape, data, -7)
        })
        .collect()
}

/// Quantize a [0,1] image batch to int8 with exponent −7 (0..127).
pub fn quantize_input(x: &[f32], bsz: usize) -> QTensor {
    let data = x
        .iter()
        .map(|&v| clamp_i8((v * 127.0).round() as i32))
        .collect();
    QTensor::from_vec(&[bsz, 1, 28, 28], data, -7)
}

/// Forward result + the activation cache for backward.
pub struct Fwd8 {
    pub logits: QTensor,
    /// post-ReLU fc1 output (input of fc2) — partition C = L−2
    pub a1: QTensor,
    /// post-ReLU fc2 output (input of fc3) — partition C = L−1
    pub a2: QTensor,
    /// flattened pool2 output (input of fc1)
    pub flat: QTensor,
    /// post-ReLU conv activations (for full BP masks/pool routing)
    pub act1: QTensor,
    pub pool1: QTensor,
    pub act2: QTensor,
    pub x: QTensor,
}

/// NITI forward; bit-identical to `lenet_int8_fwd` in the artifact.
pub fn forward(ws: &[QTensor], x: &QTensor, bsz: usize) -> Fwd8 {
    let mut h = layers::conv(x, &ws[0], bsz, 1, 28, 28, 6, 5, 2);
    layers::relu(&mut h);
    let act1 = h.clone();
    let pool1 = layers::maxpool2(&h, bsz, 6, 28, 28);
    let mut h = layers::conv(&pool1, &ws[1], bsz, 6, 14, 14, 16, 5, 2);
    layers::relu(&mut h);
    let act2 = h.clone();
    let h = layers::maxpool2(&h, bsz, 16, 14, 14);
    let flat = QTensor::from_vec(&[bsz, 784], h.data.clone(), h.exp);
    let mut a1 = layers::fc(&flat, &ws[2], bsz, 784, 120);
    layers::relu(&mut a1);
    let mut a2 = layers::fc(&a1, &ws[3], bsz, 120, 84);
    layers::relu(&mut a2);
    let logits = layers::fc(&a2, &ws[4], bsz, 84, NCLASS);
    Fwd8 {
        logits,
        a1,
        a2,
        flat,
        act1,
        pool1,
        act2,
        x: x.clone(),
    }
}

/// NITI-style int8 error at the logits: `e ≈ 127·(softmax − onehot)`,
/// computed with the 2^x trick (integer only). Exponent is nominal −7.
pub fn logits_error(logits: &QTensor, labels: &[u8], bsz: usize) -> QTensor {
    const LOG2E_Q15: i64 = 47274;
    let n = NCLASS;
    let s = logits.exp;
    let mut e = vec![0i8; bsz * n];
    for b in 0..bsz {
        let row = &logits.data[b * n..(b + 1) * n];
        let m = *row.iter().max().unwrap();
        // hat_j = log2(e) * (v - max) * 2^s  (≤ 0)
        let hat: Vec<i64> = row
            .iter()
            .map(|&v| {
                let prod = LOG2E_Q15 * ((v as i64) - (m as i64));
                if s >= 15 {
                    prod << (s - 15)
                } else {
                    prod >> (15 - s)
                }
            })
            .collect();
        let t: Vec<i64> = hat.iter().map(|&h| (h + 10).clamp(0, 10)).collect();
        let sum: i64 = t.iter().map(|&ti| 1i64 << ti).sum();
        for j in 0..n {
            let p_scaled = ((1i64 << t[j]) * 127) / sum; // ≈ 127·softmax_j
            let target = if labels[b] as usize == j { 127 } else { 0 };
            e[b * n + j] = clamp_i8((p_scaled - target) as i32);
        }
    }
    QTensor::from_vec(&[bsz, n], e, -7)
}

/// Apply an int8 update in place: `w ← clamp(w − u, ±127)`.
fn apply_update(w: &mut QTensor, u: &[i8]) {
    for (wv, &uv) in w.data.iter_mut().zip(u) {
        *wv = clamp_i8(*wv as i32 - uv as i32);
    }
}

/// BP for the last `k` ∈ {1,2,3} FC layers with gradient bitwidth
/// `b_bp` (paper Alg. 2 line 11). Updates weights in place.
pub fn tail_update(ws: &mut [QTensor], fwd: &Fwd8, labels: &[u8], k: usize, bsz: usize, b_bp: u32) {
    let e = logits_error(&fwd.logits, labels, bsz);
    match k {
        1 => {
            let (gw, _) = layers::fc_backward_acc(&fwd.a2, &ws[4], &e, bsz, 84, NCLASS);
            let u = layers::round_update(&gw, b_bp);
            apply_update(&mut ws[4], &u);
        }
        2 => {
            let (gw5, e_in) = layers::fc_backward_acc(&fwd.a2, &ws[4], &e, bsz, 84, NCLASS);
            // propagate: requantize e_in, ReLU-mask by a2 > 0
            let mut e2 = requantize(&e_in, &[bsz, 84], e.exp + ws[4].exp);
            for (ev, &av) in e2.data.iter_mut().zip(&fwd.a2.data) {
                if av <= 0 {
                    *ev = 0;
                }
            }
            let (gw4, _) = layers::fc_backward_acc(&fwd.a1, &ws[3], &e2, bsz, 120, 84);
            let u5 = layers::round_update(&gw5, b_bp);
            let u4 = layers::round_update(&gw4, b_bp);
            apply_update(&mut ws[4], &u5);
            apply_update(&mut ws[3], &u4);
        }
        3 => {
            let (gw5, e_in) = layers::fc_backward_acc(&fwd.a2, &ws[4], &e, bsz, 84, NCLASS);
            let mut e2 = requantize(&e_in, &[bsz, 84], e.exp + ws[4].exp);
            for (ev, &av) in e2.data.iter_mut().zip(&fwd.a2.data) {
                if av <= 0 {
                    *ev = 0;
                }
            }
            let (gw4, e_in) = layers::fc_backward_acc(&fwd.a1, &ws[3], &e2, bsz, 120, 84);
            let mut e1 = requantize(&e_in, &[bsz, 120], e2.exp + ws[3].exp);
            for (ev, &av) in e1.data.iter_mut().zip(&fwd.a1.data) {
                if av <= 0 {
                    *ev = 0;
                }
            }
            let (gw3, _) = layers::fc_backward_acc(&fwd.flat, &ws[2], &e1, bsz, 784, 120);
            let u5 = layers::round_update(&gw5, b_bp);
            let u4 = layers::round_update(&gw4, b_bp);
            // fc1 sees the compounded effective LR of the whole tail;
            // damp by one bit exactly as full_update does for this layer.
            let u3 = layers::round_update(&gw3, b_bp.saturating_sub(2).max(1));
            apply_update(&mut ws[4], &u5);
            apply_update(&mut ws[3], &u4);
            apply_update(&mut ws[2], &u3);
        }
        _ => panic!("tail_update supports k in {{1,2,3}}"),
    }
}

/// Full NITI BP over all five layers (the paper's Full-BP-Int8 / NITI
/// baseline). Updates weights in place with gradient bitwidth `b_bp`.
pub fn full_update(ws: &mut [QTensor], fwd: &Fwd8, labels: &[u8], bsz: usize, b_bp: u32) {
    let e = logits_error(&fwd.logits, labels, bsz);
    // fc3
    let (gw5, e_in) = layers::fc_backward_acc(&fwd.a2, &ws[4], &e, bsz, 84, NCLASS);
    let mut e2 = requantize(&e_in, &[bsz, 84], e.exp + ws[4].exp);
    for (ev, &av) in e2.data.iter_mut().zip(&fwd.a2.data) {
        if av <= 0 {
            *ev = 0;
        }
    }
    // fc2
    let (gw4, e_in) = layers::fc_backward_acc(&fwd.a1, &ws[3], &e2, bsz, 120, 84);
    let mut e1 = requantize(&e_in, &[bsz, 120], e2.exp + ws[3].exp);
    for (ev, &av) in e1.data.iter_mut().zip(&fwd.a1.data) {
        if av <= 0 {
            *ev = 0;
        }
    }
    // fc1
    let (gw3, e_in) = layers::fc_backward_acc(&fwd.flat, &ws[2], &e1, bsz, 784, 120);
    let e_flat = requantize(&e_in, &[bsz, 784], e1.exp + ws[2].exp);
    // pool2 backward: route each error to the argmax cell of act2
    let e_act2 = maxpool2_backward_i8(&e_flat, &fwd.act2, bsz, 16, 14, 14);
    // conv2 backward
    let (gw2, e_pool1) = conv_backward_acc(&e_act2, &fwd.pool1, &ws[1], bsz, 6, 14, 14, 16, 5, 2);
    let e_pool1q = requantize(&e_pool1, &[bsz, 6, 14, 14], e_act2.exp + ws[1].exp);
    // pool1 backward
    let e_act1 = maxpool2_backward_i8(&e_pool1q, &fwd.act1, bsz, 6, 28, 28);
    // conv1 backward (weight grad only — no further propagation)
    let (gw1, _) = conv_backward_acc(&e_act1, &fwd.x, &ws[0], bsz, 1, 28, 28, 6, 5, 2);
    // Per-layer update bitwidths: the raw top-b_BP-bit update that works
    // for the FC tail saturates the early layers when applied to all
    // five at once (the effective LR compounds through depth), so the
    // conv/fc1 updates are damped by 1–2 bits. This mirrors NITI's
    // per-layer gradient scaling.
    for (idx, g, bits) in [
        (4usize, gw5, b_bp),
        (3, gw4, b_bp.saturating_sub(1).max(1)),
        (2, gw3, b_bp.saturating_sub(2).max(1)),
        (1, gw2, b_bp.saturating_sub(2).max(1)),
        (0, gw1, b_bp.saturating_sub(2).max(1)),
    ] {
        let u = layers::round_update(&g, bits);
        apply_update(&mut ws[idx], &u);
    }
}

/// Route int8 pooled errors back to argmax positions of the pre-pool
/// activation (recomputing argmax from the cached activation).
fn maxpool2_backward_i8(
    e_out: &QTensor,
    act: &QTensor,
    bsz: usize,
    c: usize,
    h: usize,
    w: usize,
) -> QTensor {
    let (oh, ow) = (h / 2, w / 2);
    let mut e_in = vec![0i8; bsz * c * h * w];
    for b in 0..bsz {
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = i8::MIN;
                    let mut bidx = 0usize;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let idx = ((b * c + ch) * h + oy * 2 + dy) * w + ox * 2 + dx;
                            if act.data[idx] > best {
                                best = act.data[idx];
                                bidx = idx;
                            }
                        }
                    }
                    e_in[bidx] = e_out.data[((b * c + ch) * oh + oy) * ow + ox];
                }
            }
        }
    }
    QTensor::from_vec(&[bsz, c, h, w], e_in, e_out.exp)
}

/// Conv backward in int32: weight-gradient accumulator and input error
/// accumulator. The error is masked by the (post-ReLU) activation
/// implicitly: callers pass `e_out` already derived from masked errors,
/// and the cached activation handles pool routing.
#[allow(clippy::too_many_arguments)]
fn conv_backward_acc(
    e_out: &QTensor,
    input: &QTensor,
    wt: &QTensor,
    bsz: usize,
    cin: usize,
    h: usize,
    w: usize,
    cout: usize,
    k: usize,
    pad: usize,
) -> (Vec<i32>, Vec<i32>) {
    let (cols, oh, ow) = layers::im2col_i8(&input.data, bsz, cin, h, w, k, pad);
    let ckk = cin * k * k;
    let rows = bsz * oh * ow;
    // e as (rows, OC)
    let mut gw = vec![0i32; cout * ckk];
    let mut e_cols = vec![0i32; rows * ckk];
    for b in 0..bsz {
        for oy in 0..oh {
            for ox in 0..ow {
                let r = (b * oh + oy) * ow + ox;
                let cr = &cols[r * ckk..(r + 1) * ckk];
                for oc in 0..cout {
                    let ev = e_out.data[((b * cout + oc) * oh + oy) * ow + ox] as i32;
                    if ev == 0 {
                        continue;
                    }
                    let grow = &mut gw[oc * ckk..(oc + 1) * ckk];
                    let wrow = &wt.data[oc * ckk..(oc + 1) * ckk];
                    let erow = &mut e_cols[r * ckk..(r + 1) * ckk];
                    for e in 0..ckk {
                        grow[e] += ev * cr[e] as i32;
                        erow[e] += ev * wrow[e] as i32;
                    }
                }
            }
        }
    }
    // col2im scatter for the input error
    let mut e_in = vec![0i32; bsz * cin * h * w];
    for b in 0..bsz {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((b * oh + oy) * ow + ox) * ckk;
                for cc in 0..cin {
                    for i in 0..k {
                        let iy = oy + i;
                        if iy < pad || iy >= h + pad {
                            continue;
                        }
                        for j in 0..k {
                            let ix = ox + j;
                            if ix < pad || ix >= w + pad {
                                continue;
                            }
                            e_in[((b * cin + cc) * h + (iy - pad)) * w + (ix - pad)] +=
                                e_cols[row + (cc * k + i) * k + j];
                        }
                    }
                }
            }
        }
    }
    (gw, e_in)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_mnist;
    use crate::int8::intce;

    fn mnist_batch(bsz: usize, seed: u64) -> (QTensor, Vec<u8>) {
        let d = synth_mnist::generate(bsz, seed);
        (quantize_input(&d.x, bsz), d.labels)
    }

    #[test]
    fn forward_shapes_and_range() {
        let ws = init_params(1, 32);
        let (x, _) = mnist_batch(4, 2);
        let fwd = forward(&ws, &x, 4);
        assert_eq!(fwd.logits.dims, vec![4, NCLASS]);
        assert!(fwd.logits.data.iter().all(|&v| (-127..=127).contains(&v)));
        assert!(fwd.a1.data.iter().all(|&v| v >= 0)); // post-relu
        assert!(fwd.a2.data.iter().all(|&v| v >= 0));
    }

    #[test]
    fn forward_deterministic() {
        let ws = init_params(3, 32);
        let (x, _) = mnist_batch(2, 4);
        let f1 = forward(&ws, &x, 2);
        let f2 = forward(&ws, &x, 2);
        assert_eq!(f1.logits.data, f2.logits.data);
        assert_eq!(f1.logits.exp, f2.logits.exp);
    }

    #[test]
    fn logits_error_rows_sum_near_zero() {
        let ws = init_params(5, 32);
        let (x, labels) = mnist_batch(4, 6);
        let fwd = forward(&ws, &x, 4);
        let e = logits_error(&fwd.logits, &labels, 4);
        for b in 0..4 {
            let s: i32 = e.data[b * 10..(b + 1) * 10].iter().map(|&v| v as i32).sum();
            // Σ softmax·127 − 127 ≈ 0 up to integer-division loss (≤ n)
            assert!(s.abs() <= 12, "row {b} sum {s}");
            // label entry must be the (most) negative one
            let li = labels[b] as usize;
            assert!(e.data[b * 10 + li] <= 0);
        }
    }

    #[test]
    fn tail_update_changes_only_tail() {
        let mut ws = init_params(7, 32);
        let before: Vec<Vec<i8>> = ws.iter().map(|w| w.data.clone()).collect();
        let (x, labels) = mnist_batch(8, 8);
        let fwd = forward(&ws, &x, 8);
        tail_update(&mut ws, &fwd, &labels, 1, 8, 5);
        assert_eq!(ws[0].data, before[0]);
        assert_eq!(ws[3].data, before[3]);
        assert_ne!(ws[4].data, before[4], "fc3 must move");
    }

    #[test]
    fn tail3_updates_fc_stack_only() {
        let mut ws = init_params(13, 32);
        let before: Vec<Vec<i8>> = ws.iter().map(|w| w.data.clone()).collect();
        let (x, labels) = mnist_batch(8, 14);
        let fwd = forward(&ws, &x, 8);
        tail_update(&mut ws, &fwd, &labels, 3, 8, 5);
        assert_eq!(ws[0].data, before[0], "conv1 must stay frozen");
        assert_eq!(ws[1].data, before[1], "conv2 must stay frozen");
        assert_ne!(ws[4].data, before[4], "fc3 must move");
        let fc_moved = (2..5).filter(|&i| ws[i].data != before[i]).count();
        assert!(fc_moved >= 2, "only {fc_moved}/3 fc layers moved");
    }

    #[test]
    fn full_update_moves_all_layers() {
        let mut ws = init_params(9, 32);
        let before: Vec<Vec<i8>> = ws.iter().map(|w| w.data.clone()).collect();
        let (x, labels) = mnist_batch(8, 10);
        let fwd = forward(&ws, &x, 8);
        full_update(&mut ws, &fwd, &labels, 8, 5);
        let moved = ws
            .iter()
            .zip(&before)
            .filter(|(w, b)| w.data != **b)
            .count();
        assert!(moved >= 4, "only {moved}/5 layers moved");
    }

    #[test]
    fn training_reduces_loss_diff_vs_random() {
        // a handful of NITI full-BP steps must reduce the float CE of the
        // int8 logits on a fixed batch
        let mut ws = init_params(11, 32);
        let (x, labels) = mnist_batch(16, 12);
        let ce = |ws: &[QTensor]| -> f64 {
            let fwd = forward(ws, &x, 16);
            // reuse the intce float reference with beta == alpha shifted
            let zeros = vec![0i8; 16 * 10];
            intce::loss_diff_f32(
                &fwd.logits.data,
                fwd.logits.exp,
                &zeros,
                0,
                &labels,
                16,
                10,
            )
        };
        let l0 = ce(&ws);
        for _ in 0..10 {
            let fwd = forward(&ws, &x, 16);
            full_update(&mut ws, &fwd, &labels, 16, 5);
        }
        let l1 = ce(&ws);
        assert!(l1 < l0, "loss {l0} -> {l1}");
    }
}
