//! Tiny CLI argument parser (offline substitute for clap).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse a raw argument list (excluding argv[0]).
    ///
    /// A `--key` followed by a non-`--` token is an option; a `--key` at
    /// the end or followed by another `--key` is a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let items: Vec<String> = argv.into_iter().collect();
        let mut i = 0;
        while i < items.len() {
            let a = &items[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < items.len() && !items[i + 1].starts_with("--") {
                    out.options.insert(rest.to_string(), items[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> anyhow::Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a float, got '{s}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["train", "--model", "lenet", "--epochs=5", "--verbose"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("model"), Some("lenet"));
        assert_eq!(a.get_usize("epochs", 0).unwrap(), 5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_before_option() {
        let a = parse(&["--dry-run", "--lr", "0.01"]);
        assert!(a.flag("dry-run"));
        assert_eq!(a.get_f32("lr", 0.0).unwrap(), 0.01);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["exp", "table1", "--fast"]);
        assert_eq!(a.positional, vec!["exp", "table1"]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["--epochs", "five"]);
        assert!(a.get_usize("epochs", 0).is_err());
    }
}
