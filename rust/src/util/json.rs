//! Minimal JSON parser / writer (offline substitute for serde_json).
//!
//! Covers the full JSON grammar; used for the artifact manifest, config
//! files, checkpoints and experiment result dumps.
//!
//! Two parsers share the grammar:
//!
//! * [`parse`] builds a [`Value`] tree — convenient, allocates per
//!   node; every config/manifest/response path uses it.
//! * [`Reader`] is a pull parser for the serve hot path: it walks the
//!   same grammar token by token ([`Tok`]) without building a tree,
//!   borrowing unescaped strings straight out of the input. After a
//!   warm-up parse (which sizes its scratch buffer) it allocates
//!   nothing, which is what keeps `repro serve`'s per-request
//!   `repro_allocs_total` delta flat (see `tests/json_pull.rs`).

use std::collections::BTreeMap;
use std::fmt;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `Value::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Value {
        Value::Num(n.into())
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs for non-BMP chars.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.b.get(self.pos) == Some(&b'\\')
                                    && self.b.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 6;
                                    let c =
                                        0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(code)
                            };
                            out.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(_) => {
                    // copy one UTF-8 scalar
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.b.len() && (self.b[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize a value to compact JSON.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, None, 0);
    out
}

/// Serialize a value with 1-space indentation (matches python json.dump(indent=1)).
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, Some(1), 0);
    out
}

/// Serialize compactly into an existing buffer — no intermediate
/// `String` per call, so a long-lived connection can reuse one
/// response buffer for every body it writes (the serve hot path).
pub fn write_compact(v: &Value, out: &mut String) {
    write_value(v, out, None, 0);
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            // write! into the existing String: no intermediate
            // allocation on the per-event serialization path
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            if !a.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, item)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(item, out, indent, depth + 1);
            }
            if !o.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------
// Pull parser: the serve hot path.

/// One token from [`Reader`]: the JSON grammar, flattened. String and
/// key tokens borrow from the input when the string has no escapes,
/// and from the reader's reusable scratch buffer when it does — either
/// way, no per-token allocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Tok<'a> {
    /// `{` — the next tokens are [`Tok::Key`]/value pairs.
    ObjStart,
    /// `}` closing the innermost object.
    ObjEnd,
    /// `[` — the next tokens are the elements.
    ArrStart,
    /// `]` closing the innermost array.
    ArrEnd,
    /// An object key; its value is the next value token.
    Key(&'a str),
    /// A string value.
    Str(&'a str),
    /// A number value (same f64 representation as [`Value::Num`]).
    Num(f64),
    /// `true` / `false`.
    Bool(bool),
    /// `null`.
    Null,
}

/// Where the grammar allows the next token to sit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Expect {
    /// A value must follow (root, array element, or object value).
    Value,
    /// A container just opened: first element/key, or an immediate close.
    FirstOrEnd,
    /// After `,` inside an object: a key string must follow.
    Key,
    /// A value inside a container just finished: `,` or the closer.
    CommaOrEnd,
    /// The root value is complete; only trailing whitespace may remain.
    Eof,
}

/// Where [`Reader::read_string`] left the decoded text.
enum StrPart {
    /// Byte range of the input (no escapes: borrow it verbatim).
    Borrowed(usize, usize),
    /// The string had escapes and was decoded into the scratch buffer.
    Scratch,
}

/// Streaming pull parser over the same grammar as [`parse`], for code
/// that visits a document without building a [`Value`] tree. Call
/// [`Reader::next_token`] until it yields `Ok(None)` (document
/// complete) or an error. Strict: the token stream is validated
/// against the grammar as it is pulled, so an invalid document errors
/// at the first offending byte, exactly where [`parse`] would.
///
/// Unescaped strings are borrowed straight from the input; escaped
/// ones are decoded into one reusable scratch `String`, which
/// [`Reader::with_scratch`] lets a long-lived connection recycle
/// across documents — after warm-up the parse allocates nothing.
pub struct Reader<'a> {
    s: &'a str,
    b: &'a [u8],
    pos: usize,
    /// Container nesting as a bitstack: 1 = object, 0 = array, the
    /// innermost container in the lowest bit. Depth is capped at 64.
    stack: u64,
    depth: u32,
    state: Expect,
    scratch: String,
}

/// Deepest container nesting [`Reader`] accepts (bits in its stack).
pub const MAX_PULL_DEPTH: u32 = 64;

impl<'a> Reader<'a> {
    /// Parser over `text` with an empty scratch buffer.
    pub fn new(text: &'a str) -> Reader<'a> {
        Reader::with_scratch(text, String::new())
    }

    /// Parser over `text` reusing a scratch buffer from a previous
    /// document ([`Reader::into_scratch`]): the zero-alloc steady
    /// state for per-connection parsing.
    pub fn with_scratch(text: &'a str, mut scratch: String) -> Reader<'a> {
        scratch.clear();
        Reader {
            s: text,
            b: text.as_bytes(),
            pos: 0,
            stack: 0,
            depth: 0,
            state: Expect::Value,
            scratch,
        }
    }

    /// Recover the scratch buffer for the next document's reader.
    pub fn into_scratch(self) -> String {
        self.scratch
    }

    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while matches!(self.b.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn top_is_obj(&self) -> bool {
        self.depth > 0 && (self.stack & 1) == 1
    }

    /// A value just completed (scalar read or container closed).
    fn after_value(&mut self) {
        self.state = if self.depth == 0 { Expect::Eof } else { Expect::CommaOrEnd };
    }

    fn push(&mut self, is_obj: bool) -> Result<(), ParseError> {
        if self.depth >= MAX_PULL_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.pos += 1;
        self.stack = (self.stack << 1) | u64::from(is_obj);
        self.depth += 1;
        self.state = Expect::FirstOrEnd;
        Ok(())
    }

    fn pop(&mut self) {
        self.stack >>= 1;
        self.depth -= 1;
        self.after_value();
    }

    fn lit(&mut self, word: &str) -> Result<(), ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(())
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn resolve(&self, part: StrPart) -> &str {
        match part {
            StrPart::Borrowed(a, b) => &self.s[a..b],
            StrPart::Scratch => &self.scratch,
        }
    }

    /// Pull the next token; `Ok(None)` exactly once, at the end of a
    /// complete document. Any grammar violation — including truncated
    /// input — is an error positioned at the offending byte.
    pub fn next_token(&mut self) -> Result<Option<Tok<'_>>, ParseError> {
        loop {
            self.skip_ws();
            if self.state == Expect::Eof {
                return if self.pos == self.b.len() {
                    Ok(None)
                } else {
                    Err(self.err("trailing characters"))
                };
            }
            let Some(c) = self.b.get(self.pos).copied() else {
                return Err(self.err("unexpected end of input"));
            };
            match self.state {
                Expect::Eof => unreachable!("handled before the dispatch"),
                Expect::FirstOrEnd => {
                    if self.top_is_obj() {
                        return match c {
                            b'}' => {
                                self.pos += 1;
                                self.pop();
                                Ok(Some(Tok::ObjEnd))
                            }
                            b'"' => self.key_token(),
                            _ => Err(self.err("expected a key or '}'")),
                        };
                    }
                    if c == b']' {
                        self.pos += 1;
                        self.pop();
                        return Ok(Some(Tok::ArrEnd));
                    }
                    // an array's first element: fall through as a value
                    self.state = Expect::Value;
                }
                Expect::Key => {
                    return match c {
                        b'"' => self.key_token(),
                        _ => Err(self.err("expected a key")),
                    };
                }
                Expect::CommaOrEnd => {
                    let is_obj = self.top_is_obj();
                    match (c, is_obj) {
                        (b',', true) => {
                            self.pos += 1;
                            self.state = Expect::Key;
                        }
                        (b',', false) => {
                            self.pos += 1;
                            self.state = Expect::Value;
                        }
                        (b'}', true) => {
                            self.pos += 1;
                            self.pop();
                            return Ok(Some(Tok::ObjEnd));
                        }
                        (b']', false) => {
                            self.pos += 1;
                            self.pop();
                            return Ok(Some(Tok::ArrEnd));
                        }
                        (_, true) => return Err(self.err("expected ',' or '}'")),
                        (_, false) => return Err(self.err("expected ',' or ']'")),
                    }
                }
                Expect::Value => {
                    return match c {
                        b'{' => {
                            self.push(true)?;
                            Ok(Some(Tok::ObjStart))
                        }
                        b'[' => {
                            self.push(false)?;
                            Ok(Some(Tok::ArrStart))
                        }
                        b'"' => {
                            let part = self.read_string()?;
                            self.after_value();
                            Ok(Some(Tok::Str(self.resolve(part))))
                        }
                        b't' => {
                            self.lit("true")?;
                            self.after_value();
                            Ok(Some(Tok::Bool(true)))
                        }
                        b'f' => {
                            self.lit("false")?;
                            self.after_value();
                            Ok(Some(Tok::Bool(false)))
                        }
                        b'n' => {
                            self.lit("null")?;
                            self.after_value();
                            Ok(Some(Tok::Null))
                        }
                        c2 if c2 == b'-' || c2.is_ascii_digit() => {
                            let n = self.read_number()?;
                            self.after_value();
                            Ok(Some(Tok::Num(n)))
                        }
                        _ => Err(self.err("expected a JSON value")),
                    };
                }
            }
        }
    }

    /// An object key plus its `:` separator, leaving the reader
    /// positioned at the value.
    fn key_token(&mut self) -> Result<Option<Tok<'_>>, ParseError> {
        let part = self.read_string()?;
        self.skip_ws();
        if self.b.get(self.pos) != Some(&b':') {
            return Err(self.err("expected ':'"));
        }
        self.pos += 1;
        self.state = Expect::Value;
        Ok(Some(Tok::Key(self.resolve(part))))
    }

    /// Scan one string (opening quote at the cursor). The escape-free
    /// fast path borrows the input; escapes divert into the scratch
    /// buffer with the same decoding rules as [`parse`] (incl.
    /// surrogate pairs).
    fn read_string(&mut self) -> Result<StrPart, ParseError> {
        self.pos += 1; // opening quote
        let start = self.pos;
        loop {
            match self.b.get(self.pos) {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    let end = self.pos;
                    self.pos += 1;
                    return Ok(StrPart::Borrowed(start, end));
                }
                Some(b'\\') => break, // escapes: decode into scratch
                Some(c) if *c < 0x20 => return Err(self.err("control char in string")),
                Some(_) => self.pos += 1,
            }
        }
        self.scratch.clear();
        self.scratch.push_str(&self.s[start..self.pos]);
        loop {
            match self.b.get(self.pos).copied() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(StrPart::Scratch);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    self.decode_escape()?;
                }
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(_) => {
                    // copy one UTF-8 scalar (input is &str: boundaries hold)
                    let s0 = self.pos;
                    self.pos += 1;
                    while self.pos < self.b.len() && (self.b[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    self.scratch.push_str(&self.s[s0..self.pos]);
                }
            }
        }
    }

    /// Decode one escape (cursor just past the backslash) into scratch.
    fn decode_escape(&mut self) -> Result<(), ParseError> {
        let c = self.b.get(self.pos).copied().ok_or_else(|| self.err("bad escape"))?;
        self.pos += 1;
        match c {
            b'"' => self.scratch.push('"'),
            b'\\' => self.scratch.push('\\'),
            b'/' => self.scratch.push('/'),
            b'b' => self.scratch.push('\u{8}'),
            b'f' => self.scratch.push('\u{c}'),
            b'n' => self.scratch.push('\n'),
            b'r' => self.scratch.push('\r'),
            b't' => self.scratch.push('\t'),
            b'u' => {
                let code = self.hex4()?;
                // surrogate pairs for non-BMP chars, as in `parse`
                let ch = if (0xD800..0xDC00).contains(&code) {
                    if self.b.get(self.pos) == Some(&b'\\')
                        && self.b.get(self.pos + 1) == Some(&b'u')
                    {
                        self.pos += 2;
                        let low = self.hex4()?;
                        char::from_u32(0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00))
                    } else {
                        None
                    }
                } else {
                    char::from_u32(code)
                };
                self.scratch.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
            }
            _ => return Err(self.err("bad escape char")),
        }
        Ok(())
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let hex = self
            .b
            .get(self.pos..self.pos + 4)
            .ok_or_else(|| self.err("bad \\u escape"))?;
        let code = u32::from_str_radix(
            std::str::from_utf8(hex).map_err(|_| self.err("bad \\u escape"))?,
            16,
        )
        .map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(code)
    }

    /// Same character classes as [`parse`]'s number scanner, then one
    /// alloc-free `f64` conversion.
    fn read_number(&mut self) -> Result<f64, ParseError> {
        let start = self.pos;
        if self.b.get(self.pos) == Some(&b'-') {
            self.pos += 1;
        }
        while matches!(self.b.get(self.pos), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.b.get(self.pos) == Some(&b'.') {
            self.pos += 1;
            while matches!(self.b.get(self.pos), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.b.get(self.pos), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.b.get(self.pos), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.b.get(self.pos), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        self.s[start..self.pos].parse::<f64>().map_err(|_| self.err("bad number"))
    }
}

/// Build a [`Value`] tree by driving [`Reader`] — the differential
/// seam `tests/json_pull.rs` pins against [`parse`], and a worked
/// example of consuming the token stream with an explicit stack.
pub fn parse_pull(text: &str) -> Result<Value, ParseError> {
    enum Frame {
        Arr(Vec<Value>),
        Obj(BTreeMap<String, Value>, Option<String>),
    }
    fn attach(stack: &mut [Frame], root: &mut Option<Value>, v: Value) {
        match stack.last_mut() {
            None => *root = Some(v),
            Some(Frame::Arr(items)) => items.push(v),
            Some(Frame::Obj(map, key)) => {
                let k = key.take().expect("a key precedes every object value");
                map.insert(k, v);
            }
        }
    }
    let mut r = Reader::new(text);
    let mut stack: Vec<Frame> = Vec::new();
    let mut root: Option<Value> = None;
    while let Some(tok) = r.next_token()? {
        match tok {
            Tok::ObjStart => stack.push(Frame::Obj(BTreeMap::new(), None)),
            Tok::ArrStart => stack.push(Frame::Arr(Vec::new())),
            Tok::Key(k) => match stack.last_mut() {
                Some(Frame::Obj(_, key)) => *key = Some(k.to_string()),
                _ => unreachable!("the reader only yields keys inside objects"),
            },
            Tok::ObjEnd => match stack.pop() {
                Some(Frame::Obj(map, _)) => attach(&mut stack, &mut root, Value::Obj(map)),
                _ => unreachable!("ObjEnd closes an object frame"),
            },
            Tok::ArrEnd => match stack.pop() {
                Some(Frame::Arr(items)) => attach(&mut stack, &mut root, Value::Arr(items)),
                _ => unreachable!("ArrEnd closes an array frame"),
            },
            Tok::Str(s) => attach(&mut stack, &mut root, Value::Str(s.to_string())),
            Tok::Num(n) => attach(&mut stack, &mut root, Value::Num(n)),
            Tok::Bool(b) => attach(&mut stack, &mut root, Value::Bool(b)),
            Tok::Null => attach(&mut stack, &mut root, Value::Null),
        }
    }
    root.ok_or_else(|| ParseError { pos: 0, msg: "expected a JSON value".to_string() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Value::Null);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"entries":[{"name":"lenet_fwd_b32","shape":[32,1,28,28]}],"version":1}"#;
        let v = parse(text).unwrap();
        let v2 = parse(&to_string(&v)).unwrap();
        assert_eq!(v, v2);
        let v3 = parse(&to_string_pretty(&v)).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Value::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn pull_tokens_in_document_order() {
        let mut r = Reader::new(r#"{"a": [1, true], "b": null}"#);
        let mut toks = Vec::new();
        loop {
            match r.next_token().unwrap() {
                // keys/strings borrow from the reader, so own them here
                Some(Tok::Key(k)) => toks.push(format!("key:{k}")),
                Some(Tok::Str(s)) => toks.push(format!("str:{s}")),
                Some(t) => toks.push(format!("{t:?}")),
                None => break,
            }
        }
        assert_eq!(
            toks,
            ["ObjStart", "key:a", "ArrStart", "Num(1.0)", "Bool(true)", "ArrEnd",
             "key:b", "Null", "ObjEnd"]
        );
    }

    #[test]
    fn pull_matches_tree_parser_on_edge_cases() {
        for text in [
            "null",
            "-3.5e2",
            r#""""#,
            r#"{"nested": {"deep": [[], {}, [0.5, -0]]}}"#,
            r#""esc \"q\" \\ \n \u00e9 \ud83d\ude00 tail""#,
            r#"[9007199254740993, -9007199254740993, 1e308]"#,
        ] {
            assert_eq!(parse_pull(text).unwrap(), parse(text).unwrap(), "{text}");
        }
    }

    #[test]
    fn pull_rejects_what_the_tree_parser_rejects() {
        for text in [
            "", "{", "[1,]", "{\"a\"}", "{\"a\":}", "{\"a\":1,}", "[1 2]",
            "\"unterminated", "12 34", "{\"a\": \"\\x\"}", "tru", "nulll",
        ] {
            assert!(parse_pull(text).is_err(), "pull accepted {text:?}");
            assert!(parse(text).is_err(), "tree accepted {text:?}");
        }
    }

    #[test]
    fn pull_caps_nesting_depth() {
        let deep = "[".repeat(MAX_PULL_DEPTH as usize + 1);
        let err = parse_pull(&deep).unwrap_err();
        assert!(err.msg.contains("nesting"), "{err}");
        // one under the cap still parses (with its closers)
        let ok = format!(
            "{}{}",
            "[".repeat(MAX_PULL_DEPTH as usize),
            "]".repeat(MAX_PULL_DEPTH as usize)
        );
        assert!(parse_pull(&ok).is_ok());
    }

    #[test]
    fn pull_scratch_recycles_across_documents() {
        let mut scratch = String::new();
        for _ in 0..3 {
            let mut r = Reader::with_scratch(r#"{"k": "a\nb"}"#, scratch);
            while r.next_token().unwrap().is_some() {}
            scratch = r.into_scratch();
        }
        assert!(scratch.capacity() >= 3, "the escape decode buffer survives");
    }
}
