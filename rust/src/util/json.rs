//! Minimal JSON parser / writer (offline substitute for serde_json).
//!
//! Covers the full JSON grammar; used for the artifact manifest, config
//! files, checkpoints and experiment result dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `Value::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Value {
        Value::Num(n.into())
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs for non-BMP chars.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.b.get(self.pos) == Some(&b'\\')
                                    && self.b.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 6;
                                    let c =
                                        0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(code)
                            };
                            out.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(_) => {
                    // copy one UTF-8 scalar
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.b.len() && (self.b[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize a value to compact JSON.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, None, 0);
    out
}

/// Serialize a value with 1-space indentation (matches python json.dump(indent=1)).
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, Some(1), 0);
    out
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            if !a.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, item)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(item, out, indent, depth + 1);
            }
            if !o.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Value::Null);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"entries":[{"name":"lenet_fwd_b32","shape":[32,1,28,28]}],"version":1}"#;
        let v = parse(text).unwrap();
        let v2 = parse(&to_string(&v)).unwrap();
        assert_eq!(v, v2);
        let v3 = parse(&to_string_pretty(&v)).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Value::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }
}
