//! Plain-text table rendering for the experiment harnesses (`repro exp
//! table1` etc. print paper-style rows).

pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}-|", "-".repeat(w + 1)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a fraction as a percentage string with one decimal.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

/// Format bytes human-readably (KiB/MiB).
pub fn bytes(n: usize) -> String {
    if n >= 1 << 20 {
        format!("{:.2} MiB", n as f64 / (1 << 20) as f64)
    } else if n >= 1 << 10 {
        format!("{:.1} KiB", n as f64 / (1 << 10) as f64)
    } else {
        format!("{n} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["method", "acc"]);
        t.row(&["Full ZO".into(), "89.80".into()]);
        t.row(&["Full BP".into(), "99.10".into()]);
        let s = t.render();
        assert!(s.contains("| Full ZO |"));
        assert!(s.lines().count() == 5);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["x".into()]);
    }

    #[test]
    fn byte_format() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2048), "2.0 KiB");
        assert_eq!(bytes(5 << 20), "5.00 MiB");
    }
}
