//! Micro-benchmark harness (offline substitute for criterion).
//!
//! Used by `rust/benches/*.rs` (built with `harness = false`). Each
//! benchmark warms up, then runs timed iterations until a minimum
//! wall-clock budget is met, and reports mean / p50 / p95 per-iteration
//! times plus derived throughput.

use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }
}

pub struct Bencher {
    pub min_time: Duration,
    pub min_iters: usize,
    pub results: Vec<Stats>,
    filter: Option<String>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // `cargo bench -- <filter>` passes the filter as a positional arg.
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-') && a != "--bench");
        let fast = std::env::var("BENCH_FAST").is_ok();
        Bencher {
            min_time: if fast { Duration::from_millis(200) } else { Duration::from_secs(1) },
            min_iters: 5,
            results: Vec::new(),
            filter,
        }
    }

    /// A bencher that ignores argv — for embedding in a binary whose
    /// positional args are commands, not bench filters (`repro bench`
    /// would otherwise filter on its own subcommand word).
    pub fn unfiltered() -> Self {
        Bencher { filter: None, ..Bencher::new() }
    }

    /// Time `f`, which performs ONE iteration of the workload.
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> Option<&Stats> {
        if let Some(filt) = &self.filter {
            if !name.contains(filt.as_str()) {
                return None;
            }
        }
        // Warm-up: one untimed call (artifact compile, page faults, ...).
        black_box(f());
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.min_time || samples.len() < self.min_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
            if samples.len() > 100_000 {
                break;
            }
        }
        samples.sort();
        let total: Duration = samples.iter().sum();
        let stats = Stats {
            name: name.to_string(),
            iters: samples.len(),
            mean: total / samples.len() as u32,
            p50: samples[samples.len() / 2],
            p95: samples[(samples.len() * 95 / 100).min(samples.len() - 1)],
            min: samples[0],
        };
        println!(
            "{:<44} {:>10} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}",
            stats.name, stats.iters, stats.mean, stats.p50, stats.p95
        );
        self.results.push(stats);
        self.results.last()
    }

    /// Report a named scalar alongside the timings (e.g. a ratio).
    pub fn report_metric(&self, name: &str, value: f64, unit: &str) {
        println!("{name:<44} {value:>12.4} {unit}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_stats() {
        let mut b = Bencher::new();
        b.min_time = Duration::from_millis(5);
        let s = b.bench("noop", || 1 + 1).unwrap().clone();
        assert!(s.iters >= 5);
        assert!(s.p50 <= s.p95);
        assert!(s.min <= s.mean * 2);
    }
}
