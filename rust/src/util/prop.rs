//! Small property-testing helper (offline substitute for proptest).
//!
//! `Cases` drives a closure over many pseudo-random inputs derived from
//! a seeded generator; on failure it reports the failing case seed so
//! the case can be replayed deterministically.

use crate::rng::Rng64;

/// Runs `n` property cases. Each case gets its own deterministic RNG.
pub fn cases(n: usize, mut body: impl FnMut(&mut Rng64, usize)) {
    for case in 0..n {
        let mut rng = Rng64::new(0xE1A5_71C0 ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        body(&mut rng, case);
    }
}

/// Like [`cases`] but with a caller-chosen base seed (for independent suites).
pub fn cases_seeded(seed: u64, n: usize, mut body: impl FnMut(&mut Rng64, usize)) {
    for case in 0..n {
        let mut rng = Rng64::new(seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        body(&mut rng, case);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_are_deterministic() {
        let mut first = Vec::new();
        cases(5, |rng, _| first.push(rng.next_u64()));
        let mut second = Vec::new();
        cases(5, |rng, _| second.push(rng.next_u64()));
        assert_eq!(first, second);
    }

    #[test]
    fn cases_differ_across_indices() {
        let mut vals = Vec::new();
        cases(8, |rng, _| vals.push(rng.next_u64()));
        vals.dedup();
        assert_eq!(vals.len(), 8);
    }
}
