//! Self-contained utility substrates (the build box is offline, so the
//! usual serde/clap/criterion/proptest stack is re-implemented in-tree
//! at the size this project needs).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod table;
