//! Softmax cross-entropy: mean loss over the batch and its logits
//! gradient — the BP-tail seed (paper Alg. 1, line 23).

/// Numerically-stable mean CE from logits (B,N) and one-hot labels.
pub fn cross_entropy(logits: &[f32], onehot: &[f32], bsz: usize, n: usize) -> f32 {
    let mut total = 0.0f64;
    for row in 0..bsz {
        let lg = &logits[row * n..(row + 1) * n];
        let oh = &onehot[row * n..(row + 1) * n];
        let m = lg.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse: f64 = m as f64
            + lg.iter()
                .map(|&v| ((v - m) as f64).exp())
                .sum::<f64>()
                .ln();
        let picked: f64 = lg.iter().zip(oh).map(|(&l, &o)| (l * o) as f64).sum();
        total += lse - picked;
    }
    (total / bsz as f64) as f32
}

/// Softmax probabilities per row.
pub fn softmax(logits: &[f32], bsz: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; bsz * n];
    for row in 0..bsz {
        let lg = &logits[row * n..(row + 1) * n];
        let m = lg.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for (j, &v) in lg.iter().enumerate() {
            let e = (v - m).exp();
            out[row * n + j] = e;
            sum += e;
        }
        for j in 0..n {
            out[row * n + j] /= sum;
        }
    }
    out
}

/// ∂(mean CE)/∂logits = (softmax − onehot) / B.
pub fn cross_entropy_grad(logits: &[f32], onehot: &[f32], bsz: usize, n: usize) -> Vec<f32> {
    let mut g = softmax(logits, bsz, n);
    for (gv, &ov) in g.iter_mut().zip(onehot) {
        *gv = (*gv - ov) / bsz as f32;
    }
    g
}

/// Classification accuracy over the first `real` rows.
pub fn accuracy(logits: &[f32], labels: &[u8], real: usize, n: usize) -> (usize, usize) {
    let mut correct = 0;
    for row in 0..real {
        let lg = &logits[row * n..(row + 1) * n];
        let pred = lg
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == labels[row] as usize {
            correct += 1;
        }
    }
    (correct, real)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn uniform_logits_loss_is_log_n() {
        let logits = vec![0.0f32; 4 * 10];
        let mut onehot = vec![0.0f32; 4 * 10];
        for r in 0..4 {
            onehot[r * 10 + r] = 1.0;
        }
        let l = cross_entropy(&logits, &onehot, 4, 10);
        assert!((l - (10.0f32).ln()).abs() < 1e-6);
    }

    #[test]
    fn grad_rows_sum_to_zero() {
        prop::cases(10, |rng, _| {
            let (b, n) = (4usize, 10usize);
            let logits: Vec<f32> = (0..b * n).map(|_| rng.normal() * 3.0).collect();
            let mut onehot = vec![0.0f32; b * n];
            for r in 0..b {
                onehot[r * n + (rng.next_u64() % n as u64) as usize] = 1.0;
            }
            let g = cross_entropy_grad(&logits, &onehot, b, n);
            for r in 0..b {
                let s: f32 = g[r * n..(r + 1) * n].iter().sum();
                assert!(s.abs() < 1e-6, "row sum {s}");
            }
        });
    }

    #[test]
    fn grad_matches_finite_difference() {
        prop::cases(5, |rng, _| {
            let (b, n) = (3usize, 5usize);
            let logits: Vec<f32> = (0..b * n).map(|_| rng.normal()).collect();
            let mut onehot = vec![0.0f32; b * n];
            for r in 0..b {
                onehot[r * n + (rng.next_u64() % n as u64) as usize] = 1.0;
            }
            let g = cross_entropy_grad(&logits, &onehot, b, n);
            let eps = 1e-3f32;
            for idx in 0..b * n {
                let mut lp = logits.clone();
                lp[idx] += eps;
                let mut lm = logits.clone();
                lm[idx] -= eps;
                let fd = (cross_entropy(&lp, &onehot, b, n)
                    - cross_entropy(&lm, &onehot, b, n))
                    / (2.0 * eps);
                assert!((fd - g[idx]).abs() < 1e-3, "fd {fd} vs {}", g[idx]);
            }
        });
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        prop::cases(5, |rng, _| {
            let (b, n) = (4usize, 7usize);
            let logits: Vec<f32> = (0..b * n).map(|_| rng.normal() * 5.0).collect();
            let s = softmax(&logits, b, n);
            for r in 0..b {
                let sum: f32 = s[r * n..(r + 1) * n].iter().sum();
                assert!((sum - 1.0).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn stability_extreme_logits() {
        let logits = vec![1000.0f32, -1000.0];
        let onehot = vec![1.0f32, 0.0];
        let l = cross_entropy(&logits, &onehot, 1, 2);
        assert!(l.is_finite() && l >= 0.0);
    }

    #[test]
    fn accuracy_counts() {
        let logits = vec![0.9, 0.1, 0.2, 0.8];
        let (c, t) = accuracy(&logits, &[0, 0], 2, 2);
        assert_eq!((c, t), (1, 2));
    }
}
