//! Fully-connected layer: forward and backward on flat slices.

use crate::tensor::ops;

/// y(B,N) = x(B,K) @ w(K,N) + b(N), optional ReLU.
pub fn forward(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    bsz: usize,
    k: usize,
    n: usize,
    relu: bool,
) -> Vec<f32> {
    let mut y = vec![0.0f32; bsz * n];
    ops::matmul_f32_into(x, w, &mut y, bsz, k, n);
    for row in 0..bsz {
        for j in 0..n {
            let v = &mut y[row * n + j];
            *v += b[j];
            if relu && *v < 0.0 {
                *v = 0.0;
            }
        }
    }
    y
}

/// Backward through `y = act(x @ w + b)`.
///
/// * `e_out` — upstream error ∂L/∂y, `(B,N)`
/// * `y` — the layer's own output (used for the ReLU mask)
/// * returns `(gw (K,N), gb (N,), e_in (B,K))`
#[allow(clippy::too_many_arguments)]
pub fn backward(
    x: &[f32],
    w: &[f32],
    y: &[f32],
    e_out: &[f32],
    bsz: usize,
    k: usize,
    n: usize,
    relu: bool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    // ReLU mask: zero error where the output was clamped.
    let mut e = e_out.to_vec();
    if relu {
        for (ev, &yv) in e.iter_mut().zip(y) {
            if yv <= 0.0 {
                *ev = 0.0;
            }
        }
    }
    // gw = xᵀ e : (K,B)@(B,N)
    let mut gw = vec![0.0f32; k * n];
    for row in 0..bsz {
        let xr = &x[row * k..(row + 1) * k];
        let er = &e[row * n..(row + 1) * n];
        for (kk, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let grow = &mut gw[kk * n..(kk + 1) * n];
            for (gv, &ev) in grow.iter_mut().zip(er) {
                *gv += xv * ev;
            }
        }
    }
    // gb = column sums of e
    let mut gb = vec![0.0f32; n];
    for row in 0..bsz {
        for j in 0..n {
            gb[j] += e[row * n + j];
        }
    }
    // e_in = e @ wᵀ : (B,N)@(N,K)
    let mut e_in = vec![0.0f32; bsz * k];
    for row in 0..bsz {
        let er = &e[row * n..(row + 1) * n];
        let ei = &mut e_in[row * k..(row + 1) * k];
        for (kk, eiv) in ei.iter_mut().enumerate() {
            let wrow = &w[kk * n..(kk + 1) * n];
            let mut acc = 0.0f32;
            for (&ev, &wv) in er.iter().zip(wrow) {
                acc += ev * wv;
            }
            *eiv = acc;
        }
    }
    (gw, gb, e_in)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn forward_known() {
        // x = [1,2], w = [[1,0],[0,1]], b = [10, -10]
        let y = forward(&[1.0, 2.0], &[1.0, 0.0, 0.0, 1.0], &[10.0, -10.0], 1, 2, 2, false);
        assert_eq!(y, vec![11.0, -8.0]);
        let yr = forward(&[1.0, 2.0], &[1.0, 0.0, 0.0, 1.0], &[10.0, -10.0], 1, 2, 2, true);
        assert_eq!(yr, vec![11.0, 0.0]);
    }

    /// Finite-difference check of the full backward.
    #[test]
    fn backward_matches_finite_difference() {
        prop::cases(5, |rng, _| {
            let (bsz, k, n) = (3usize, 5usize, 4usize);
            let x: Vec<f32> = (0..bsz * k).map(|_| rng.normal()).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
            // scalar loss L = sum(y^2)/2 so e_out = y
            let loss = |w: &[f32], b: &[f32]| -> f64 {
                let y = forward(&x, w, b, bsz, k, n, true);
                y.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / 2.0
            };
            let y = forward(&x, &w, &b, bsz, k, n, true);
            let (gw, gb, _) = backward(&x, &w, &y, &y, bsz, k, n, true);
            let eps = 1e-3f32;
            for idx in [0usize, k * n / 2, k * n - 1] {
                let mut wp = w.clone();
                wp[idx] += eps;
                let mut wm = w.clone();
                wm[idx] -= eps;
                let fd = (loss(&wp, &b) - loss(&wm, &b)) / (2.0 * eps as f64);
                assert!(
                    (fd - gw[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                    "gw[{idx}]: fd {fd} vs {}",
                    gw[idx]
                );
            }
            for idx in 0..n {
                let mut bp = b.clone();
                bp[idx] += eps;
                let mut bm = b.clone();
                bm[idx] -= eps;
                let fd = (loss(&w, &bp) - loss(&w, &bm)) / (2.0 * eps as f64);
                assert!(
                    (fd - gb[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                    "gb[{idx}]: fd {fd} vs {}",
                    gb[idx]
                );
            }
        });
    }

    #[test]
    fn backward_input_error_finite_difference() {
        prop::cases(3, |rng, _| {
            let (bsz, k, n) = (2usize, 4usize, 3usize);
            let x: Vec<f32> = (0..bsz * k).map(|_| rng.normal()).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
            let b: Vec<f32> = vec![0.0; n];
            let loss = |x: &[f32]| -> f64 {
                let y = forward(x, &w, &b, bsz, k, n, false);
                y.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / 2.0
            };
            let y = forward(&x, &w, &b, bsz, k, n, false);
            let (_, _, e_in) = backward(&x, &w, &y, &y, bsz, k, n, false);
            let eps = 1e-3f32;
            for idx in 0..x.len() {
                let mut xp = x.clone();
                xp[idx] += eps;
                let mut xm = x.clone();
                xm[idx] -= eps;
                let fd = (loss(&xp) - loss(&xm)) / (2.0 * eps as f64);
                assert!(
                    (fd - e_in[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                    "e_in[{idx}]: fd {fd} vs {}",
                    e_in[idx]
                );
            }
        });
    }
}
