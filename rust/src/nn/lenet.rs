//! Native LeNet-5 (paper variant): forward, tail-BP and full-BP.
//!
//! Parameter ABI (identical to python/compile/model.py::LENET_PARAMS):
//! `[conv1_w, conv1_b, conv2_w, conv2_b, fc1_w, fc1_b, fc2_w, fc2_b,
//!   fc3_w, fc3_b]` — 107,786 params total.

use super::{conv, linear, loss, pool, Forward, TailGrads};

pub const NCLASS: usize = 10;
pub const FLAT: usize = 784; // 16 * 7 * 7

/// `(name, shape)` of every parameter in ABI order.
pub const PARAM_SPECS: [(&str, &[usize]); 10] = [
    ("conv1_w", &[6, 1, 5, 5]),
    ("conv1_b", &[6]),
    ("conv2_w", &[16, 6, 5, 5]),
    ("conv2_b", &[16]),
    ("fc1_w", &[784, 120]),
    ("fc1_b", &[120]),
    ("fc2_w", &[120, 84]),
    ("fc2_b", &[84]),
    ("fc3_w", &[84, 10]),
    ("fc3_b", &[10]),
];

/// Activation cache for the full backward pass.
pub struct Cache {
    pub x: Vec<f32>,
    pub cols1: Vec<f32>,
    pub out1: Vec<f32>,
    pub arg1: Vec<u32>,
    pub pool1: Vec<f32>,
    pub cols2: Vec<f32>,
    pub out2: Vec<f32>,
    pub arg2: Vec<u32>,
    pub flat: Vec<f32>,
    pub a1: Vec<f32>,
    pub a2: Vec<f32>,
    pub logits: Vec<f32>,
    pub bsz: usize,
}

/// Forward + loss. `x` is `(B,1,28,28)` flattened, `y` one-hot `(B,10)`.
pub fn forward(params: &[Vec<f32>], x: &[f32], y: &[f32], bsz: usize) -> (Forward, Cache) {
    assert_eq!(params.len(), 10);
    assert_eq!(x.len(), bsz * 784);
    let (out1, cols1) =
        conv::forward(x, &params[0], &params[1], bsz, 1, 28, 28, 6, 5, 2, true);
    let (pool1, arg1) = pool::maxpool2_forward(&out1, bsz, 6, 28, 28);
    let (out2, cols2) =
        conv::forward(&pool1, &params[2], &params[3], bsz, 6, 14, 14, 16, 5, 2, true);
    let (pool2, arg2) = pool::maxpool2_forward(&out2, bsz, 16, 14, 14);
    let flat = pool2; // (B,16,7,7) row-major == (B,784)
    let a1 = linear::forward(&flat, &params[4], &params[5], bsz, FLAT, 120, true);
    let a2 = linear::forward(&a1, &params[6], &params[7], bsz, 120, 84, true);
    let logits = linear::forward(&a2, &params[8], &params[9], bsz, 84, NCLASS, false);
    let l = loss::cross_entropy(&logits, y, bsz, NCLASS);
    (
        Forward {
            loss: l,
            logits: logits.clone(),
            act_c3: flat.clone(),
            act_c2: a1.clone(),
            act_c1: a2.clone(),
        },
        Cache {
            x: x.to_vec(),
            cols1,
            out1,
            arg1,
            pool1,
            cols2,
            out2,
            arg2,
            flat,
            a1,
            a2,
            logits,
            bsz,
        },
    )
}

/// BP for the last `k` ∈ {1,2,3} FC layers (the full classifier
/// stack at k = 3). Inputs are the partition activations returned by
/// `forward`.
pub fn tail_grads(params: &[Vec<f32>], fwd: &Forward, y: &[f32], k: usize, bsz: usize) -> TailGrads {
    match k {
        1 => {
            let a = &fwd.act_c1; // (B,84)
            let logits = linear::forward(a, &params[8], &params[9], bsz, 84, NCLASS, false);
            let e = loss::cross_entropy_grad(&logits, y, bsz, NCLASS);
            let (gw, gb, _) =
                linear::backward(a, &params[8], &logits, &e, bsz, 84, NCLASS, false);
            vec![(8, gw), (9, gb)]
        }
        2 => {
            let a1 = &fwd.act_c2; // (B,120)
            let a2 = linear::forward(a1, &params[6], &params[7], bsz, 120, 84, true);
            let logits = linear::forward(&a2, &params[8], &params[9], bsz, 84, NCLASS, false);
            let e = loss::cross_entropy_grad(&logits, y, bsz, NCLASS);
            let (gw5, gb5, e2) =
                linear::backward(&a2, &params[8], &logits, &e, bsz, 84, NCLASS, false);
            let (gw4, gb4, _) =
                linear::backward(a1, &params[6], &a2, &e2, bsz, 120, 84, true);
            vec![(6, gw4), (7, gb4), (8, gw5), (9, gb5)]
        }
        3 => {
            let flat = &fwd.act_c3; // (B,784)
            assert_eq!(
                flat.len(),
                bsz * FLAT,
                "tail_grads k=3 needs the act_c3 partition activation (this backend did not supply it)"
            );
            let a1 = linear::forward(flat, &params[4], &params[5], bsz, FLAT, 120, true);
            let a2 = linear::forward(&a1, &params[6], &params[7], bsz, 120, 84, true);
            let logits = linear::forward(&a2, &params[8], &params[9], bsz, 84, NCLASS, false);
            let e = loss::cross_entropy_grad(&logits, y, bsz, NCLASS);
            let (gw5, gb5, e2) =
                linear::backward(&a2, &params[8], &logits, &e, bsz, 84, NCLASS, false);
            let (gw4, gb4, e1) =
                linear::backward(&a1, &params[6], &a2, &e2, bsz, 120, 84, true);
            let (gw3, gb3, _) =
                linear::backward(flat, &params[4], &a1, &e1, bsz, FLAT, 120, true);
            vec![(4, gw3), (5, gb3), (6, gw4), (7, gb4), (8, gw5), (9, gb5)]
        }
        _ => panic!("tail_grads supports k in {{1,2,3}}, got {k}"),
    }
}

/// Full backward: gradients for all 10 parameters (Full-BP baseline).
pub fn full_grads(params: &[Vec<f32>], cache: &Cache, y: &[f32]) -> Vec<Vec<f32>> {
    let bsz = cache.bsz;
    let e = loss::cross_entropy_grad(&cache.logits, y, bsz, NCLASS);
    let (gw5, gb5, e_a2) =
        linear::backward(&cache.a2, &params[8], &cache.logits, &e, bsz, 84, NCLASS, false);
    let (gw4, gb4, e_a1) =
        linear::backward(&cache.a1, &params[6], &cache.a2, &e_a2, bsz, 120, 84, true);
    let (gw3, gb3, e_flat) =
        linear::backward(&cache.flat, &params[4], &cache.a1, &e_a1, bsz, FLAT, 120, true);
    // flat == pool2 output; route error back through pool2 -> conv2
    let e_out2 = pool::maxpool2_backward(&e_flat, &cache.arg2, bsz * 16 * 14 * 14);
    let (gw2, gb2, e_pool1) = conv::backward(
        &e_out2, &cache.out2, &cache.cols2, &params[2], bsz, 6, 14, 14, 16, 5, 2, true,
    );
    let e_out1 = pool::maxpool2_backward(&e_pool1, &cache.arg1, bsz * 6 * 28 * 28);
    let (gw1, gb1, _) = conv::backward(
        &e_out1, &cache.out1, &cache.cols1, &params[0], bsz, 1, 28, 28, 6, 5, 2, true,
    );
    vec![gw1, gb1, gw2, gb2, gw3, gb3, gw4, gb4, gw5, gb5]
}

/// Total parameter count (must equal the paper's 107,786).
pub fn param_count() -> usize {
    PARAM_SPECS
        .iter()
        .map(|(_, s)| s.iter().product::<usize>())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng64;

    pub fn init_params(seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng64::new(seed);
        PARAM_SPECS
            .iter()
            .map(|(_, shape)| {
                let n: usize = shape.iter().product();
                // conv (OC,C,KH,KW): fan_in = C*KH*KW; fc (K,N): fan_in = K
                let fan_in = match shape.len() {
                    4 => shape[1] * shape[2] * shape[3],
                    2 => shape[0],
                    _ => n,
                };
                let mut v = vec![0.0f32; n];
                rng.fill_kaiming_uniform(&mut v, fan_in);
                v
            })
            .collect()
    }

    fn batch(bsz: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng64::new(seed);
        let x: Vec<f32> = (0..bsz * 784).map(|_| rng.uniform()).collect();
        let mut y = vec![0.0f32; bsz * 10];
        for r in 0..bsz {
            y[r * 10 + (rng.next_u64() % 10) as usize] = 1.0;
        }
        (x, y)
    }

    #[test]
    fn param_count_matches_paper() {
        assert_eq!(param_count(), 107_786);
    }

    #[test]
    fn forward_shapes_and_loss_near_log10() {
        let params = init_params(1);
        let (x, y) = batch(4, 2);
        let (fwd, _) = forward(&params, &x, &y, 4);
        assert_eq!(fwd.logits.len(), 40);
        assert_eq!(fwd.act_c2.len(), 4 * 120);
        assert_eq!(fwd.act_c1.len(), 4 * 84);
        // random init -> a finite, plausible CE (exact magnitude depends
        // on the unnormalized uniform inputs used here)
        assert!(fwd.loss.is_finite() && fwd.loss > 0.5 && fwd.loss < 20.0, "loss {}", fwd.loss);
    }

    #[test]
    fn tail1_matches_full_grads() {
        let params = init_params(3);
        let (x, y) = batch(3, 4);
        let (fwd, cache) = forward(&params, &x, &y, 3);
        let tail = tail_grads(&params, &fwd, &y, 1, 3);
        let full = full_grads(&params, &cache, &y);
        for (idx, g) in &tail {
            for (a, b) in g.iter().zip(&full[*idx]) {
                assert!((a - b).abs() < 1e-5, "param {idx}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn tail2_matches_full_grads() {
        let params = init_params(5);
        let (x, y) = batch(3, 6);
        let (fwd, cache) = forward(&params, &x, &y, 3);
        let tail = tail_grads(&params, &fwd, &y, 2, 3);
        let full = full_grads(&params, &cache, &y);
        assert_eq!(tail.len(), 4);
        for (idx, g) in &tail {
            for (a, b) in g.iter().zip(&full[*idx]) {
                assert!((a - b).abs() < 1e-5, "param {idx}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn tail3_matches_full_grads() {
        let params = init_params(11);
        let (x, y) = batch(3, 12);
        let (fwd, cache) = forward(&params, &x, &y, 3);
        let tail = tail_grads(&params, &fwd, &y, 3, 3);
        let full = full_grads(&params, &cache, &y);
        assert_eq!(tail.len(), 6, "k=3 covers the whole classifier stack");
        for (idx, g) in &tail {
            for (a, b) in g.iter().zip(&full[*idx]) {
                assert!((a - b).abs() < 1e-5, "param {idx}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn full_grads_finite_difference_spotcheck() {
        let params = init_params(7);
        let (x, y) = batch(2, 8);
        let (_, cache) = forward(&params, &x, &y, 2);
        let grads = full_grads(&params, &cache, &y);
        let eps = 2e-3f32;
        // spot-check a few weights in each layer
        for (pi, n_checks) in [(0usize, 2usize), (2, 2), (4, 2), (8, 3)] {
            let plen = params[pi].len();
            for t in 0..n_checks {
                let idx = (t * 7919) % plen;
                let mut pp = params.clone();
                pp[pi][idx] += eps;
                let (fp, _) = forward(&pp, &x, &y, 2);
                let mut pm = params.clone();
                pm[pi][idx] -= eps;
                let (fm, _) = forward(&pm, &x, &y, 2);
                let fd = (fp.loss - fm.loss) / (2.0 * eps);
                let g = grads[pi][idx];
                assert!(
                    (fd - g).abs() < 5e-2 * (1.0 + fd.abs().max(g.abs())),
                    "param {pi}[{idx}]: fd {fd} vs bp {g}"
                );
            }
        }
    }

    #[test]
    fn sgd_step_decreases_loss() {
        let mut params = init_params(9);
        let (x, y) = batch(8, 10);
        let (f0, cache) = forward(&params, &x, &y, 8);
        let grads = full_grads(&params, &cache, &y);
        for (p, g) in params.iter_mut().zip(&grads) {
            crate::tensor::ops::axpy(-0.05, g, p);
        }
        let (f1, _) = forward(&params, &x, &y, 8);
        assert!(f1.loss < f0.loss, "{} -> {}", f0.loss, f1.loss);
    }
}
