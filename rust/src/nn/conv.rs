//! 2-D convolution (stride 1, symmetric zero padding) via im2col + GEMM
//! — the same lowering the Pallas conv kernel uses (DESIGN.md
//! §Hardware-Adaptation), so numerics line up across engines.
//!
//! Patch-matrix layout matches python/compile/kernels/ref.py::im2col:
//! rows are (b, oy, ox), columns are c*kh*kw + i*kw + j.

use crate::tensor::ops;

/// im2col: x (B,C,H,W) -> cols (B*OH*OW, C*KH*KW), stride 1.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    x: &[f32],
    bsz: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    pad: usize,
) -> (Vec<f32>, usize, usize) {
    let oh = h + 2 * pad - kh + 1;
    let ow = w + 2 * pad - kw + 1;
    let ckk = c * kh * kw;
    let mut cols = vec![0.0f32; bsz * oh * ow * ckk];
    for b in 0..bsz {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((b * oh + oy) * ow + ox) * ckk;
                for cc in 0..c {
                    for i in 0..kh {
                        let iy = oy + i;
                        if iy < pad || iy >= h + pad {
                            continue; // zero padding
                        }
                        let src_y = iy - pad;
                        for j in 0..kw {
                            let ix = ox + j;
                            if ix < pad || ix >= w + pad {
                                continue;
                            }
                            let src_x = ix - pad;
                            cols[row + (cc * kh + i) * kw + j] =
                                x[((b * c + cc) * h + src_y) * w + src_x];
                        }
                    }
                }
            }
        }
    }
    (cols, oh, ow)
}

/// col2im: scatter-add cols (B*OH*OW, C*KH*KW) back to (B,C,H,W).
#[allow(clippy::too_many_arguments)]
pub fn col2im(
    cols: &[f32],
    bsz: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    pad: usize,
) -> Vec<f32> {
    let oh = h + 2 * pad - kh + 1;
    let ow = w + 2 * pad - kw + 1;
    let ckk = c * kh * kw;
    let mut x = vec![0.0f32; bsz * c * h * w];
    for b in 0..bsz {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((b * oh + oy) * ow + ox) * ckk;
                for cc in 0..c {
                    for i in 0..kh {
                        let iy = oy + i;
                        if iy < pad || iy >= h + pad {
                            continue;
                        }
                        let src_y = iy - pad;
                        for j in 0..kw {
                            let ix = ox + j;
                            if ix < pad || ix >= w + pad {
                                continue;
                            }
                            let src_x = ix - pad;
                            x[((b * c + cc) * h + src_y) * w + src_x] +=
                                cols[row + (cc * kh + i) * kw + j];
                        }
                    }
                }
            }
        }
    }
    x
}

/// Conv forward. Weights `(OC, C, KH, KW)` row-major, bias `(OC,)`.
/// Output layout `(B, OC, OH, OW)`. Returns `(out, cols)` — the patch
/// matrix is cached for the backward pass.
#[allow(clippy::too_many_arguments)]
pub fn forward(
    x: &[f32],
    wt: &[f32],
    bias: &[f32],
    bsz: usize,
    cin: usize,
    h: usize,
    w: usize,
    cout: usize,
    k: usize,
    pad: usize,
    relu: bool,
) -> (Vec<f32>, Vec<f32>) {
    let (cols, oh, ow) = im2col(x, bsz, cin, h, w, k, k, pad);
    let ckk = cin * k * k;
    let rows = bsz * oh * ow;
    // out_mat (rows, OC) = cols (rows, CKK) @ wT (CKK, OC)
    let mut wt_t = vec![0.0f32; ckk * cout];
    for oc in 0..cout {
        for e in 0..ckk {
            wt_t[e * cout + oc] = wt[oc * ckk + e];
        }
    }
    let mut out_mat = vec![0.0f32; rows * cout];
    ops::matmul_f32_into(&cols, &wt_t, &mut out_mat, rows, ckk, cout);
    // (rows, OC) -> (B, OC, OH, OW) with bias and ReLU
    let mut out = vec![0.0f32; bsz * cout * oh * ow];
    for b in 0..bsz {
        for oy in 0..oh {
            for ox in 0..ow {
                let r = ((b * oh + oy) * ow + ox) * cout;
                for oc in 0..cout {
                    let mut v = out_mat[r + oc] + bias[oc];
                    if relu && v < 0.0 {
                        v = 0.0;
                    }
                    out[((b * cout + oc) * oh + oy) * ow + ox] = v;
                }
            }
        }
    }
    (out, cols)
}

/// Conv backward.
///
/// * `e_out` — upstream error on the (post-ReLU) output `(B,OC,OH,OW)`
/// * `out` — the forward output (ReLU mask source)
/// * `cols` — cached patch matrix from forward
///
/// Returns `(gw (OC,C,KH,KW), gb (OC,), e_in (B,C,H,W))`.
#[allow(clippy::too_many_arguments)]
pub fn backward(
    e_out: &[f32],
    out: &[f32],
    cols: &[f32],
    wt: &[f32],
    bsz: usize,
    cin: usize,
    h: usize,
    w: usize,
    cout: usize,
    k: usize,
    pad: usize,
    relu: bool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let oh = h + 2 * pad - k + 1;
    let ow = w + 2 * pad - k + 1;
    let rows = bsz * oh * ow;
    let ckk = cin * k * k;
    // e as (rows, OC) with ReLU mask applied
    let mut e_mat = vec![0.0f32; rows * cout];
    for b in 0..bsz {
        for oc in 0..cout {
            for oy in 0..oh {
                for ox in 0..ow {
                    let src = ((b * cout + oc) * oh + oy) * ow + ox;
                    let mut ev = e_out[src];
                    if relu && out[src] <= 0.0 {
                        ev = 0.0;
                    }
                    e_mat[((b * oh + oy) * ow + ox) * cout + oc] = ev;
                }
            }
        }
    }
    // gw (OC, CKK) = e_matᵀ (OC, rows) @ cols (rows, CKK)
    let mut gw = vec![0.0f32; cout * ckk];
    for r in 0..rows {
        let er = &e_mat[r * cout..(r + 1) * cout];
        let cr = &cols[r * ckk..(r + 1) * ckk];
        for (oc, &ev) in er.iter().enumerate() {
            if ev == 0.0 {
                continue;
            }
            let grow = &mut gw[oc * ckk..(oc + 1) * ckk];
            for (gv, &cv) in grow.iter_mut().zip(cr) {
                *gv += ev * cv;
            }
        }
    }
    // gb = per-channel sums
    let mut gb = vec![0.0f32; cout];
    for r in 0..rows {
        for oc in 0..cout {
            gb[oc] += e_mat[r * cout + oc];
        }
    }
    // e_cols (rows, CKK) = e_mat (rows, OC) @ wt (OC, CKK); then col2im
    let mut e_cols = vec![0.0f32; rows * ckk];
    ops::matmul_f32_into(&e_mat, wt, &mut e_cols, rows, cout, ckk);
    let e_in = col2im(&e_cols, bsz, cin, h, w, k, k, pad);
    (gw, gb, e_in)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// 1×1 input, 1×1 kernel: conv is a scalar multiply.
    #[test]
    fn conv_1x1_scalar() {
        let (out, _) = forward(&[3.0], &[2.0], &[1.0], 1, 1, 1, 1, 1, 1, 0, false);
        assert_eq!(out, vec![7.0]);
    }

    #[test]
    fn conv_known_3x3() {
        // 3x3 image, 3x3 all-ones kernel, pad 1: center output = sum of all
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let wt = vec![1.0f32; 9];
        let (out, _) = forward(&x, &wt, &[0.0], 1, 1, 3, 3, 1, 3, 1, false);
        assert_eq!(out.len(), 9);
        assert_eq!(out[4], 45.0); // center sees everything
        assert_eq!(out[0], 1.0 + 2.0 + 4.0 + 5.0); // corner
    }

    #[test]
    fn im2col_col2im_adjoint() {
        // <im2col(x), c> == <x, col2im(c)> (adjointness), the property
        // conv backward relies on.
        prop::cases(5, |rng, _| {
            let (b, c, h, w, k, pad) = (2usize, 3usize, 6usize, 5usize, 3usize, 1usize);
            let x: Vec<f32> = (0..b * c * h * w).map(|_| rng.normal()).collect();
            let (cols, oh, ow) = im2col(&x, b, c, h, w, k, k, pad);
            let cvec: Vec<f32> = (0..b * oh * ow * c * k * k).map(|_| rng.normal()).collect();
            let lhs: f64 = cols.iter().zip(&cvec).map(|(a, b)| (a * b) as f64).sum();
            let back = col2im(&cvec, b, c, h, w, k, k, pad);
            let rhs: f64 = x.iter().zip(&back).map(|(a, b)| (a * b) as f64).sum();
            assert!((lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
        });
    }

    #[test]
    fn backward_matches_finite_difference() {
        prop::cases(3, |rng, _| {
            let (b, cin, h, w, cout, k, pad) = (1usize, 2, 5, 5, 3, 3, 1);
            let x: Vec<f32> = (0..b * cin * h * w).map(|_| rng.normal()).collect();
            let wt: Vec<f32> = (0..cout * cin * k * k).map(|_| rng.normal() * 0.3).collect();
            let bias: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.1).collect();
            let loss = |wt: &[f32]| -> f64 {
                let (out, _) = forward(&x, wt, &bias, b, cin, h, w, cout, k, pad, true);
                out.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / 2.0
            };
            let (out, cols) = forward(&x, &wt, &bias, b, cin, h, w, cout, k, pad, true);
            let (gw, _gb, _) =
                backward(&out, &out, &cols, &wt, b, cin, h, w, cout, k, pad, true);
            let eps = 1e-3f32;
            for idx in [0usize, wt.len() / 2, wt.len() - 1] {
                let mut wp = wt.clone();
                wp[idx] += eps;
                let mut wm = wt.clone();
                wm[idx] -= eps;
                let fd = (loss(&wp) - loss(&wm)) / (2.0 * eps as f64);
                assert!(
                    (fd - gw[idx] as f64).abs() < 3e-2 * (1.0 + fd.abs()),
                    "gw[{idx}] fd {fd} vs {}",
                    gw[idx]
                );
            }
        });
    }

    #[test]
    fn input_grad_finite_difference() {
        prop::cases(2, |rng, _| {
            let (b, cin, h, w, cout, k, pad) = (1usize, 1, 4, 4, 2, 3, 1);
            let x: Vec<f32> = (0..b * cin * h * w).map(|_| rng.normal()).collect();
            let wt: Vec<f32> = (0..cout * cin * k * k).map(|_| rng.normal() * 0.3).collect();
            let bias = vec![0.0f32; cout];
            let loss = |x: &[f32]| -> f64 {
                let (out, _) = forward(x, &wt, &bias, b, cin, h, w, cout, k, pad, false);
                out.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / 2.0
            };
            let (out, cols) = forward(&x, &wt, &bias, b, cin, h, w, cout, k, pad, false);
            let (_, _, e_in) =
                backward(&out, &out, &cols, &wt, b, cin, h, w, cout, k, pad, false);
            let eps = 1e-3f32;
            for idx in 0..x.len() {
                let mut xp = x.clone();
                xp[idx] += eps;
                let mut xm = x.clone();
                xm[idx] -= eps;
                let fd = (loss(&xp) - loss(&xm)) / (2.0 * eps as f64);
                assert!(
                    (fd - e_in[idx] as f64).abs() < 3e-2 * (1.0 + fd.abs()),
                    "e_in[{idx}] fd {fd} vs {}",
                    e_in[idx]
                );
            }
        });
    }

    #[test]
    fn lenet_shapes() {
        let x = vec![0.5f32; 2 * 1 * 28 * 28];
        let wt = vec![0.01f32; 6 * 1 * 5 * 5];
        let bias = vec![0.0f32; 6];
        let (out, _) = forward(&x, &wt, &bias, 2, 1, 28, 28, 6, 5, 2, true);
        assert_eq!(out.len(), 2 * 6 * 28 * 28);
    }
}
