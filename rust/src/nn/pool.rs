//! Pooling: 2×2/stride-2 spatial max pool (LeNet) and global max pool
//! over points (PointNet), both with argmax caching for backward.

/// 2×2 stride-2 max pool over (B,C,H,W). Returns (out, argmax) where
/// argmax stores the flat input index chosen for each output cell.
pub fn maxpool2_forward(
    x: &[f32],
    bsz: usize,
    c: usize,
    h: usize,
    w: usize,
) -> (Vec<f32>, Vec<u32>) {
    assert!(h % 2 == 0 && w % 2 == 0, "maxpool2 needs even dims");
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![0.0f32; bsz * c * oh * ow];
    let mut arg = vec![0u32; bsz * c * oh * ow];
    for b in 0..bsz {
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_idx = 0u32;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let iy = oy * 2 + dy;
                            let ix = ox * 2 + dx;
                            let idx = ((b * c + ch) * h + iy) * w + ix;
                            if x[idx] > best {
                                best = x[idx];
                                best_idx = idx as u32;
                            }
                        }
                    }
                    let o = ((b * c + ch) * oh + oy) * ow + ox;
                    out[o] = best;
                    arg[o] = best_idx;
                }
            }
        }
    }
    (out, arg)
}

/// Max-pool backward: scatter upstream error to the argmax positions.
pub fn maxpool2_backward(e_out: &[f32], arg: &[u32], input_len: usize) -> Vec<f32> {
    let mut e_in = vec![0.0f32; input_len];
    for (ev, &idx) in e_out.iter().zip(arg) {
        e_in[idx as usize] += ev;
    }
    e_in
}

/// Global max over the point axis: x (B,N,F) -> (out (B,F), argmax (B,F)).
pub fn global_maxpool_forward(x: &[f32], bsz: usize, n: usize, f: usize) -> (Vec<f32>, Vec<u32>) {
    let mut out = vec![f32::NEG_INFINITY; bsz * f];
    let mut arg = vec![0u32; bsz * f];
    for b in 0..bsz {
        for p in 0..n {
            let row = &x[(b * n + p) * f..(b * n + p + 1) * f];
            for (j, &v) in row.iter().enumerate() {
                if v > out[b * f + j] {
                    out[b * f + j] = v;
                    arg[b * f + j] = ((b * n + p) * f + j) as u32;
                }
            }
        }
    }
    (out, arg)
}

pub fn global_maxpool_backward(e_out: &[f32], arg: &[u32], input_len: usize) -> Vec<f32> {
    let mut e_in = vec![0.0f32; input_len];
    for (ev, &idx) in e_out.iter().zip(arg) {
        e_in[idx as usize] += ev;
    }
    e_in
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn maxpool_known() {
        #[rustfmt::skip]
        let x = vec![
            1.0, 2.0, 3.0, 4.0,
            5.0, 6.0, 7.0, 8.0,
            9.0, 1.0, 2.0, 3.0,
            4.0, 5.0, 6.0, 7.0,
        ];
        let (out, arg) = maxpool2_forward(&x, 1, 1, 4, 4);
        assert_eq!(out, vec![6.0, 8.0, 9.0, 7.0]);
        assert_eq!(arg[0], 5);
    }

    #[test]
    fn maxpool_backward_routes_to_argmax() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let (_, arg) = maxpool2_forward(&x, 1, 1, 2, 2);
        let e_in = maxpool2_backward(&[10.0], &arg, 4);
        assert_eq!(e_in, vec![0.0, 0.0, 0.0, 10.0]);
    }

    #[test]
    fn maxpool_output_ge_inputs() {
        prop::cases(5, |rng, _| {
            let (b, c, h, w) = (2usize, 3usize, 8usize, 8usize);
            let x: Vec<f32> = (0..b * c * h * w).map(|_| rng.normal()).collect();
            let (out, _) = maxpool2_forward(&x, b, c, h, w);
            let mx_in = x.iter().cloned().fold(f32::MIN, f32::max);
            let mx_out = out.iter().cloned().fold(f32::MIN, f32::max);
            assert_eq!(mx_in, mx_out);
        });
    }

    #[test]
    fn global_maxpool_known() {
        // B=1, N=3, F=2
        let x = vec![1.0, 9.0, 5.0, 2.0, 3.0, 4.0];
        let (out, arg) = global_maxpool_forward(&x, 1, 3, 2);
        assert_eq!(out, vec![5.0, 9.0]);
        assert_eq!(arg, vec![2, 1]);
        let e_in = global_maxpool_backward(&[1.0, 2.0], &arg, 6);
        assert_eq!(e_in, vec![0.0, 2.0, 1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn global_maxpool_permutation_invariant() {
        prop::cases(5, |rng, _| {
            let (b, n, f) = (2usize, 8usize, 4usize);
            let x: Vec<f32> = (0..b * n * f).map(|_| rng.normal()).collect();
            let (out1, _) = global_maxpool_forward(&x, b, n, f);
            // swap two points in each batch row
            let mut x2 = x.clone();
            for bi in 0..b {
                for j in 0..f {
                    x2.swap((bi * n) * f + j, (bi * n + 5) * f + j);
                }
            }
            let (out2, _) = global_maxpool_forward(&x2, b, n, f);
            assert_eq!(out1, out2);
        });
    }
}
