//! Native PointNet (vanilla, no T-nets): shared per-point MLPs, global
//! max-pool aggregation, 3-layer classification head.
//!
//! Parameter ABI (identical to python/compile/model.py::pointnet_params):
//! `[feat1_w, feat1_b, ..., feat5_w, feat5_b, head1_w, head1_b,
//!   head2_w, head2_b, head3_w, head3_b]`.

use super::{linear, loss, pool, Forward, TailGrads};

pub const FEAT_DIMS: [usize; 6] = [3, 64, 64, 64, 128, 1024];
pub const HEAD_DIMS: [usize; 4] = [1024, 512, 256, 40];

/// `(name, shape)` of every parameter in ABI order for `ncls` classes.
pub fn param_specs(ncls: usize) -> Vec<(String, Vec<usize>)> {
    let mut out = Vec::new();
    for i in 0..FEAT_DIMS.len() - 1 {
        out.push((format!("feat{}_w", i + 1), vec![FEAT_DIMS[i], FEAT_DIMS[i + 1]]));
        out.push((format!("feat{}_b", i + 1), vec![FEAT_DIMS[i + 1]]));
    }
    let hd = [HEAD_DIMS[0], HEAD_DIMS[1], HEAD_DIMS[2], ncls];
    for i in 0..3 {
        out.push((format!("head{}_w", i + 1), vec![hd[i], hd[i + 1]]));
        out.push((format!("head{}_b", i + 1), vec![hd[i + 1]]));
    }
    out
}

pub fn param_count(ncls: usize) -> usize {
    param_specs(ncls)
        .iter()
        .map(|(_, s)| s.iter().product::<usize>())
        .sum()
}

/// Activation cache for full backward.
pub struct Cache {
    /// Per-point activations after each feat layer (index 0 is the input).
    pub feats: Vec<Vec<f32>>,
    pub pool_arg: Vec<u32>,
    pub global: Vec<f32>,
    pub h1: Vec<f32>,
    pub h2: Vec<f32>,
    pub logits: Vec<f32>,
    pub bsz: usize,
    pub npoints: usize,
    pub ncls: usize,
}

/// Forward + loss. `x` is `(B,N,3)` flattened, `y` one-hot `(B,ncls)`.
pub fn forward(
    params: &[Vec<f32>],
    x: &[f32],
    y: &[f32],
    bsz: usize,
    npoints: usize,
    ncls: usize,
) -> (Forward, Cache) {
    assert_eq!(params.len(), 16);
    assert_eq!(x.len(), bsz * npoints * 3);
    let rows = bsz * npoints;
    let mut feats: Vec<Vec<f32>> = vec![x.to_vec()];
    let mut cur = x.to_vec();
    for i in 0..5 {
        let (k, n) = (FEAT_DIMS[i], FEAT_DIMS[i + 1]);
        cur = linear::forward(&cur, &params[2 * i], &params[2 * i + 1], rows, k, n, true);
        feats.push(cur.clone());
    }
    let (global, pool_arg) = pool::global_maxpool_forward(&cur, bsz, npoints, 1024);
    let h1 = linear::forward(&global, &params[10], &params[11], bsz, 1024, 512, true);
    let h2 = linear::forward(&h1, &params[12], &params[13], bsz, 512, 256, true);
    let logits = linear::forward(&h2, &params[14], &params[15], bsz, 256, ncls, false);
    let l = loss::cross_entropy(&logits, y, bsz, ncls);
    (
        Forward {
            loss: l,
            logits: logits.clone(),
            act_c3: global.clone(),
            act_c2: h1.clone(),
            act_c1: h2.clone(),
        },
        Cache {
            feats,
            pool_arg,
            global,
            h1,
            h2,
            logits,
            bsz,
            npoints,
            ncls,
        },
    )
}

/// BP for the last `k` ∈ {1,2,3} head FC layers (the whole
/// classification head at k = 3).
pub fn tail_grads(
    params: &[Vec<f32>],
    fwd: &Forward,
    y: &[f32],
    k: usize,
    bsz: usize,
    ncls: usize,
) -> TailGrads {
    match k {
        1 => {
            let a = &fwd.act_c1; // h2 (B,256)
            let logits = linear::forward(a, &params[14], &params[15], bsz, 256, ncls, false);
            let e = loss::cross_entropy_grad(&logits, y, bsz, ncls);
            let (gw, gb, _) =
                linear::backward(a, &params[14], &logits, &e, bsz, 256, ncls, false);
            vec![(14, gw), (15, gb)]
        }
        2 => {
            let h1 = &fwd.act_c2; // (B,512)
            let h2 = linear::forward(h1, &params[12], &params[13], bsz, 512, 256, true);
            let logits = linear::forward(&h2, &params[14], &params[15], bsz, 256, ncls, false);
            let e = loss::cross_entropy_grad(&logits, y, bsz, ncls);
            let (gw3, gb3, e2) =
                linear::backward(&h2, &params[14], &logits, &e, bsz, 256, ncls, false);
            let (gw2, gb2, _) =
                linear::backward(h1, &params[12], &h2, &e2, bsz, 512, 256, true);
            vec![(12, gw2), (13, gb2), (14, gw3), (15, gb3)]
        }
        3 => {
            let global = &fwd.act_c3; // (B,1024)
            assert_eq!(
                global.len(),
                bsz * 1024,
                "tail_grads k=3 needs the act_c3 partition activation (this backend did not supply it)"
            );
            let h1 = linear::forward(global, &params[10], &params[11], bsz, 1024, 512, true);
            let h2 = linear::forward(&h1, &params[12], &params[13], bsz, 512, 256, true);
            let logits = linear::forward(&h2, &params[14], &params[15], bsz, 256, ncls, false);
            let e = loss::cross_entropy_grad(&logits, y, bsz, ncls);
            let (gw3, gb3, e2) =
                linear::backward(&h2, &params[14], &logits, &e, bsz, 256, ncls, false);
            let (gw2, gb2, e1) =
                linear::backward(&h1, &params[12], &h2, &e2, bsz, 512, 256, true);
            let (gw1, gb1, _) =
                linear::backward(global, &params[10], &h1, &e1, bsz, 1024, 512, true);
            vec![(10, gw1), (11, gb1), (12, gw2), (13, gb2), (14, gw3), (15, gb3)]
        }
        _ => panic!("tail_grads supports k in {{1,2,3}}, got {k}"),
    }
}

/// Full backward: gradients for all 16 parameters.
pub fn full_grads(params: &[Vec<f32>], cache: &Cache, y: &[f32]) -> Vec<Vec<f32>> {
    let (bsz, npoints, ncls) = (cache.bsz, cache.npoints, cache.ncls);
    let rows = bsz * npoints;
    let e = loss::cross_entropy_grad(&cache.logits, y, bsz, ncls);
    let (gw_h3, gb_h3, e_h2) =
        linear::backward(&cache.h2, &params[14], &cache.logits, &e, bsz, 256, ncls, false);
    let (gw_h2, gb_h2, e_h1) =
        linear::backward(&cache.h1, &params[12], &cache.h2, &e_h2, bsz, 512, 256, true);
    let (gw_h1, gb_h1, e_global) =
        linear::backward(&cache.global, &params[10], &cache.h1, &e_h1, bsz, 1024, 512, true);
    let mut e_cur = pool::global_maxpool_backward(&e_global, &cache.pool_arg, rows * 1024);
    let mut grads_rev: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
    for i in (0..5).rev() {
        let (k, n) = (FEAT_DIMS[i], FEAT_DIMS[i + 1]);
        let (gw, gb, e_in) = linear::backward(
            &cache.feats[i],
            &params[2 * i],
            &cache.feats[i + 1],
            &e_cur,
            rows,
            k,
            n,
            true,
        );
        grads_rev.push((gw, gb));
        e_cur = e_in;
    }
    let mut out = Vec::with_capacity(16);
    for (gw, gb) in grads_rev.into_iter().rev() {
        out.push(gw);
        out.push(gb);
    }
    out.push(gw_h1);
    out.push(gb_h1);
    out.push(gw_h2);
    out.push(gb_h2);
    out.push(gw_h3);
    out.push(gb_h3);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng64;

    fn init_params(seed: u64, ncls: usize) -> Vec<Vec<f32>> {
        let mut rng = Rng64::new(seed);
        param_specs(ncls)
            .iter()
            .map(|(_, shape)| {
                let n: usize = shape.iter().product();
                let fan_in = if shape.len() > 1 { shape[0] } else { n };
                let mut v = vec![0.0f32; n];
                rng.fill_kaiming_uniform(&mut v, fan_in);
                v
            })
            .collect()
    }

    fn batch(bsz: usize, npoints: usize, ncls: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng64::new(seed);
        let x: Vec<f32> = (0..bsz * npoints * 3).map(|_| rng.normal()).collect();
        let mut y = vec![0.0f32; bsz * ncls];
        for r in 0..bsz {
            y[r * ncls + (rng.next_u64() % ncls as u64) as usize] = 1.0;
        }
        (x, y)
    }

    #[test]
    fn param_count_near_paper() {
        let n = param_count(40);
        // paper reports 816,744 for its PointNet variant; ours is the
        // no-T-net equivalent and must land within 0.5%.
        assert!((n as f64 - 816_744.0).abs() / 816_744.0 < 0.005, "{n}");
    }

    #[test]
    fn forward_shapes() {
        let params = init_params(1, 40);
        let (x, y) = batch(2, 16, 40, 2);
        let (fwd, cache) = forward(&params, &x, &y, 2, 16, 40);
        assert_eq!(fwd.logits.len(), 80);
        assert_eq!(fwd.act_c2.len(), 2 * 512);
        assert_eq!(fwd.act_c1.len(), 2 * 256);
        assert_eq!(cache.global.len(), 2 * 1024);
        // global max-pool inflates activations at random init; just
        // require a finite, plausible CE
        assert!(fwd.loss.is_finite() && fwd.loss > 1.0 && fwd.loss < 25.0, "loss {}", fwd.loss);
    }

    #[test]
    fn permutation_invariance() {
        let params = init_params(3, 40);
        let (x, y) = batch(2, 8, 40, 4);
        let (f1, _) = forward(&params, &x, &y, 2, 8, 40);
        // reverse the point order within each cloud
        let mut x2 = x.clone();
        for b in 0..2 {
            for p in 0..8 {
                for k in 0..3 {
                    x2[(b * 8 + p) * 3 + k] = x[(b * 8 + (7 - p)) * 3 + k];
                }
            }
        }
        let (f2, _) = forward(&params, &x2, &y, 2, 8, 40);
        for (a, b) in f1.logits.iter().zip(&f2.logits) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn tail_matches_full() {
        let params = init_params(5, 40);
        let (x, y) = batch(2, 8, 40, 6);
        let (fwd, cache) = forward(&params, &x, &y, 2, 8, 40);
        let full = full_grads(&params, &cache, &y);
        for k in [1usize, 2, 3] {
            for (idx, g) in tail_grads(&params, &fwd, &y, k, 2, 40) {
                for (a, b) in g.iter().zip(&full[idx]) {
                    assert!((a - b).abs() < 1e-5, "k={k} param {idx}");
                }
            }
        }
    }

    #[test]
    fn full_grads_finite_difference_spotcheck() {
        let params = init_params(7, 10);
        let (x, y) = batch(2, 6, 10, 8);
        let (_, cache) = forward(&params, &x, &y, 2, 6, 10);
        let grads = full_grads(&params, &cache, &y);
        let eps = 2e-3f32;
        for (pi, n_checks) in [(0usize, 2usize), (4, 2), (10, 2), (14, 2)] {
            let plen = params[pi].len();
            for t in 0..n_checks {
                let idx = (t * 104_729) % plen;
                let mut pp = params.clone();
                pp[pi][idx] += eps;
                let (fp, _) = forward(&pp, &x, &y, 2, 6, 10);
                let mut pm = params.clone();
                pm[pi][idx] -= eps;
                let (fm, _) = forward(&pm, &x, &y, 2, 6, 10);
                let fd = (fp.loss - fm.loss) / (2.0 * eps);
                let g = grads[pi][idx];
                assert!(
                    (fd - g).abs() < 5e-2 * (1.0 + fd.abs().max(g.abs())),
                    "param {pi}[{idx}]: fd {fd} vs bp {g}"
                );
            }
        }
    }

    #[test]
    fn sgd_step_decreases_loss() {
        let mut params = init_params(9, 10);
        let (x, y) = batch(4, 8, 10, 10);
        let (f0, cache) = forward(&params, &x, &y, 4, 8, 10);
        let grads = full_grads(&params, &cache, &y);
        for (p, g) in params.iter_mut().zip(&grads) {
            crate::tensor::ops::axpy(-5e-3, g, p);
        }
        let (f1, _) = forward(&params, &x, &y, 4, 8, 10);
        assert!(f1.loss < f0.loss, "{} -> {}", f0.loss, f1.loss);
    }
}
