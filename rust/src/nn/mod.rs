//! Native f32 NN engine — the pure-rust counterpart of the paper's
//! C++/NEON on-device implementation.
//!
//! Implements forward, tail-backward and full-backward for the two
//! paper models (LeNet-5, PointNet) on plain slices, mirroring the AOT
//! artifact ABI exactly (same parameter ordering, same activations
//! returned at the ZO/BP partition points). Integration tests assert
//! this engine and the XLA engine agree on loss/logits to float
//! tolerance.

pub mod conv;
pub mod lenet;
pub mod linear;
pub mod loss;
pub mod pointnet;
pub mod pool;

/// Forward result common to both models and both engines.
#[derive(Debug, Clone)]
pub struct Forward {
    /// Mean cross-entropy loss over the batch.
    pub loss: f32,
    /// Logits, `bsz * nclass` row-major.
    pub logits: Vec<f32>,
    /// Activation entering the third-from-last FC — the classifier
    /// stack's input (`flat` for LeNet, `global` for PointNet). Needed
    /// only for `bp-tail=3`; backends that cannot supply it (older XLA
    /// artifact sets) leave it empty and reject k = 3 tails.
    pub act_c3: Vec<f32>,
    /// Post-ReLU activation entering the second-to-last FC (`a_fc1`/`h1`).
    pub act_c2: Vec<f32>,
    /// Post-ReLU activation entering the last FC (`a_fc2`/`h2`).
    pub act_c1: Vec<f32>,
}

/// Gradients for the BP tail: `(name_index, grad)` pairs in parameter
/// ABI order, covering only the last `bp_layers` FC layers.
pub type TailGrads = Vec<(usize, Vec<f32>)>;
