//! Per-phase wall-clock telemetry — the instrumentation behind the
//! paper's Fig. 7 execution-time breakdown (Forward / ZO Perturb /
//! ZO Update / BP / Loss / Data).

use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Data,
    Forward,
    Loss,
    ZoPerturb,
    ZoUpdate,
    /// Tail-BP of the last `k` FC layers (ElasticZO methods).
    BpBackward,
    /// A fused Full-BP forward+backward+SGD step (`Engine::full_step`);
    /// distinct from [`Phase::Forward`] so Fig.-7-style breakdowns don't
    /// conflate whole BP steps with plain forward passes.
    BpStep,
    Eval,
    Other,
}

pub const ALL_PHASES: [Phase; 9] = [
    Phase::Data,
    Phase::Forward,
    Phase::Loss,
    Phase::ZoPerturb,
    Phase::ZoUpdate,
    Phase::BpBackward,
    Phase::BpStep,
    Phase::Eval,
    Phase::Other,
];

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Data => "Data",
            Phase::Forward => "Forward",
            Phase::Loss => "Loss",
            Phase::ZoPerturb => "ZO Perturb",
            Phase::ZoUpdate => "ZO Update",
            Phase::BpBackward => "BP Backward",
            Phase::BpStep => "BP Step",
            Phase::Eval => "Eval",
            Phase::Other => "Other",
        }
    }

    fn index(&self) -> usize {
        ALL_PHASES.iter().position(|p| p == self).unwrap()
    }
}

/// Accumulates time per phase across a run.
#[derive(Debug, Clone, Default)]
pub struct PhaseTimer {
    totals: [Duration; 9],
    counts: [u64; 9],
}

impl PhaseTimer {
    pub fn new() -> PhaseTimer {
        PhaseTimer::default()
    }

    /// Time a closure under a phase.
    pub fn time<R>(&mut self, phase: Phase, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.add(phase, t0.elapsed());
        r
    }

    pub fn add(&mut self, phase: Phase, d: Duration) {
        let i = phase.index();
        self.totals[i] += d;
        self.counts[i] += 1;
    }

    pub fn total(&self, phase: Phase) -> Duration {
        self.totals[phase.index()]
    }

    pub fn grand_total(&self) -> Duration {
        self.totals.iter().sum()
    }

    /// Fraction of total time per phase (Fig. 7's stacked bars).
    pub fn fractions(&self) -> Vec<(Phase, f64)> {
        let g = self.grand_total().as_secs_f64().max(1e-12);
        ALL_PHASES
            .iter()
            .map(|&p| (p, self.total(p).as_secs_f64() / g))
            .collect()
    }

    pub fn merge(&mut self, other: &PhaseTimer) {
        for i in 0..ALL_PHASES.len() {
            self.totals[i] += other.totals[i];
            self.counts[i] += other.counts[i];
        }
    }

    /// Render a Fig.7-style breakdown table.
    pub fn report(&self, title: &str) -> String {
        let mut out = format!("-- {title} (total {:?})\n", self.grand_total());
        for (p, f) in self.fractions() {
            if f > 0.0 {
                out.push_str(&format!(
                    "   {:<12} {:>8.2?}  {:>5.1}%  ({} calls)\n",
                    p.name(),
                    self.total(p),
                    f * 100.0,
                    self.counts[p.index()]
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_accumulates() {
        let mut t = PhaseTimer::new();
        let r = t.time(Phase::Forward, || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(r, 42);
        assert!(t.total(Phase::Forward) >= Duration::from_millis(5));
        assert_eq!(t.total(Phase::ZoUpdate), Duration::ZERO);
    }

    #[test]
    fn fractions_sum_to_one() {
        let mut t = PhaseTimer::new();
        t.add(Phase::Forward, Duration::from_millis(80));
        t.add(Phase::ZoPerturb, Duration::from_millis(15));
        t.add(Phase::BpBackward, Duration::from_millis(5));
        let sum: f64 = t.fractions().iter().map(|(_, f)| f).sum();
        assert!((sum - 1.0).abs() < 1e-9);
        let fwd = t.fractions()[1].1;
        assert!((fwd - 0.8).abs() < 1e-9);
    }

    #[test]
    fn merge_adds() {
        let mut a = PhaseTimer::new();
        a.add(Phase::Forward, Duration::from_millis(10));
        let mut b = PhaseTimer::new();
        b.add(Phase::Forward, Duration::from_millis(20));
        a.merge(&b);
        assert_eq!(a.total(Phase::Forward), Duration::from_millis(30));
    }

    #[test]
    fn bp_step_is_a_distinct_phase() {
        let mut t = PhaseTimer::new();
        t.add(Phase::BpStep, Duration::from_millis(10));
        assert_eq!(t.total(Phase::Forward), Duration::ZERO);
        assert_eq!(t.total(Phase::BpBackward), Duration::ZERO);
        assert_eq!(t.total(Phase::BpStep), Duration::from_millis(10));
        assert!(t.report("x").contains("BP Step"));
    }

    #[test]
    fn report_contains_phases() {
        let mut t = PhaseTimer::new();
        t.add(Phase::Forward, Duration::from_millis(10));
        let r = t.report("epoch");
        assert!(r.contains("Forward"));
        assert!(!r.contains("ZO Update")); // zero phases omitted
    }
}
