//! Per-phase wall-clock telemetry — the instrumentation behind the
//! paper's Fig. 7 execution-time breakdown (Forward / ZO Perturb /
//! ZO Update / BP / Loss / Data).

use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Data,
    Forward,
    Loss,
    ZoPerturb,
    ZoUpdate,
    /// Tail-BP of the last `k` FC layers (ElasticZO methods).
    BpBackward,
    /// A fused Full-BP forward+backward+SGD step (`Engine::full_step`);
    /// distinct from [`Phase::Forward`] so Fig.-7-style breakdowns don't
    /// conflate whole BP steps with plain forward passes.
    BpStep,
    Eval,
    Other,
}

pub const ALL_PHASES: [Phase; 9] = [
    Phase::Data,
    Phase::Forward,
    Phase::Loss,
    Phase::ZoPerturb,
    Phase::ZoUpdate,
    Phase::BpBackward,
    Phase::BpStep,
    Phase::Eval,
    Phase::Other,
];

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Data => "Data",
            Phase::Forward => "Forward",
            Phase::Loss => "Loss",
            Phase::ZoPerturb => "ZO Perturb",
            Phase::ZoUpdate => "ZO Update",
            Phase::BpBackward => "BP Backward",
            Phase::BpStep => "BP Step",
            Phase::Eval => "Eval",
            Phase::Other => "Other",
        }
    }

    /// Inverse of [`Phase::name`] — how phases come back off the wire
    /// (`/cluster/epoch` payloads, journal replay).
    pub fn parse(name: &str) -> Option<Phase> {
        ALL_PHASES.into_iter().find(|p| p.name() == name)
    }

    fn index(&self) -> usize {
        ALL_PHASES.iter().position(|p| p == self).unwrap()
    }
}

/// One phase's share of a bounded window (an epoch): seconds spent and
/// number of timed calls. This is the unit serialized into
/// `EpochStats` so remote agents ship the same Fig.-7 breakdown the
/// local workers keep in memory.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseDelta {
    pub phase: Phase,
    pub seconds: f64,
    pub calls: u64,
}

/// Accumulates time per phase across a run.
#[derive(Debug, Clone, Default)]
pub struct PhaseTimer {
    totals: [Duration; 9],
    counts: [u64; 9],
}

impl PhaseTimer {
    pub fn new() -> PhaseTimer {
        PhaseTimer::default()
    }

    /// Time a closure under a phase.
    pub fn time<R>(&mut self, phase: Phase, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.add(phase, t0.elapsed());
        r
    }

    pub fn add(&mut self, phase: Phase, d: Duration) {
        let i = phase.index();
        self.totals[i] += d;
        self.counts[i] += 1;
    }

    pub fn total(&self, phase: Phase) -> Duration {
        self.totals[phase.index()]
    }

    /// Number of timed calls recorded for a phase.
    pub fn count(&self, phase: Phase) -> u64 {
        self.counts[phase.index()]
    }

    /// Per-phase deltas accumulated since `mark` (a clone of this
    /// timer taken earlier, e.g. at epoch start). Phases with no new
    /// time are omitted.
    pub fn deltas_since(&self, mark: &PhaseTimer) -> Vec<PhaseDelta> {
        ALL_PHASES
            .iter()
            .filter_map(|&p| {
                let i = p.index();
                let d = self.totals[i].saturating_sub(mark.totals[i]);
                let calls = self.counts[i].saturating_sub(mark.counts[i]);
                (d > Duration::ZERO || calls > 0).then(|| PhaseDelta {
                    phase: p,
                    seconds: d.as_secs_f64(),
                    calls,
                })
            })
            .collect()
    }

    /// Merge a wire-format delta back into the timer (registry side of
    /// [`PhaseTimer::deltas_since`]).
    pub fn add_delta(&mut self, d: &PhaseDelta) {
        let i = d.phase.index();
        self.totals[i] += Duration::from_secs_f64(d.seconds.max(0.0));
        self.counts[i] += d.calls;
    }

    pub fn grand_total(&self) -> Duration {
        self.totals.iter().sum()
    }

    /// Fraction of total time per phase (Fig. 7's stacked bars).
    pub fn fractions(&self) -> Vec<(Phase, f64)> {
        let g = self.grand_total().as_secs_f64().max(1e-12);
        ALL_PHASES
            .iter()
            .map(|&p| (p, self.total(p).as_secs_f64() / g))
            .collect()
    }

    pub fn merge(&mut self, other: &PhaseTimer) {
        for i in 0..ALL_PHASES.len() {
            self.totals[i] += other.totals[i];
            self.counts[i] += other.counts[i];
        }
    }

    /// Render a Fig.7-style breakdown table.
    pub fn report(&self, title: &str) -> String {
        let mut out = format!("-- {title} (total {:?})\n", self.grand_total());
        for (p, f) in self.fractions() {
            if f > 0.0 {
                out.push_str(&format!(
                    "   {:<12} {:>8.2?}  {:>5.1}%  ({} calls)\n",
                    p.name(),
                    self.total(p),
                    f * 100.0,
                    self.counts[p.index()]
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_accumulates() {
        let mut t = PhaseTimer::new();
        let r = t.time(Phase::Forward, || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(r, 42);
        assert!(t.total(Phase::Forward) >= Duration::from_millis(5));
        assert_eq!(t.total(Phase::ZoUpdate), Duration::ZERO);
    }

    #[test]
    fn fractions_sum_to_one() {
        let mut t = PhaseTimer::new();
        t.add(Phase::Forward, Duration::from_millis(80));
        t.add(Phase::ZoPerturb, Duration::from_millis(15));
        t.add(Phase::BpBackward, Duration::from_millis(5));
        let sum: f64 = t.fractions().iter().map(|(_, f)| f).sum();
        assert!((sum - 1.0).abs() < 1e-9);
        let fwd = t.fractions()[1].1;
        assert!((fwd - 0.8).abs() < 1e-9);
    }

    #[test]
    fn merge_adds() {
        let mut a = PhaseTimer::new();
        a.add(Phase::Forward, Duration::from_millis(10));
        let mut b = PhaseTimer::new();
        b.add(Phase::Forward, Duration::from_millis(20));
        a.merge(&b);
        assert_eq!(a.total(Phase::Forward), Duration::from_millis(30));
    }

    #[test]
    fn bp_step_is_a_distinct_phase() {
        let mut t = PhaseTimer::new();
        t.add(Phase::BpStep, Duration::from_millis(10));
        assert_eq!(t.total(Phase::Forward), Duration::ZERO);
        assert_eq!(t.total(Phase::BpBackward), Duration::ZERO);
        assert_eq!(t.total(Phase::BpStep), Duration::from_millis(10));
        assert!(t.report("x").contains("BP Step"));
    }

    #[test]
    fn parse_inverts_name() {
        for p in ALL_PHASES {
            assert_eq!(Phase::parse(p.name()), Some(p));
        }
        assert_eq!(Phase::parse("NotAPhase"), None);
    }

    #[test]
    fn deltas_roundtrip_through_add_delta() {
        let mut t = PhaseTimer::new();
        t.add(Phase::Forward, Duration::from_millis(100));
        let mark = t.clone();
        t.add(Phase::Forward, Duration::from_millis(40));
        t.add(Phase::ZoUpdate, Duration::from_millis(10));
        let deltas = t.deltas_since(&mark);
        assert_eq!(deltas.len(), 2, "only phases with new time appear: {deltas:?}");
        assert_eq!(deltas[0].phase, Phase::Forward);
        assert!((deltas[0].seconds - 0.04).abs() < 1e-9);
        assert_eq!(deltas[0].calls, 1);

        let mut merged = mark.clone();
        for d in &deltas {
            merged.add_delta(d);
        }
        // seconds go through f64 on the wire: equal to nanosecond noise
        for p in [Phase::Forward, Phase::ZoUpdate] {
            let err = (merged.total(p).as_secs_f64() - t.total(p).as_secs_f64()).abs();
            assert!(err < 1e-6, "{p:?} drifted by {err}");
        }
        assert_eq!(merged.count(Phase::Forward), t.count(Phase::Forward));
    }

    #[test]
    fn report_contains_phases() {
        let mut t = PhaseTimer::new();
        t.add(Phase::Forward, Duration::from_millis(10));
        let r = t.report("epoch");
        assert!(r.contains("Forward"));
        assert!(!r.contains("ZO Update")); // zero phases omitted
    }
}
