//! Run configuration: JSON config files + CLI overrides → one validated
//! [`Config`] consumed by the launcher (`repro train`/`exp`).
//!
//! Precedence: defaults < `--config file.json` < individual CLI flags.

use crate::coordinator::{
    CheckpointPolicy, DpAggregate, DpSpec, ElasticSpec, EngineKind, Method, PrecisionSpec,
    TrainSpec, ZoGradMode,
};
use crate::data::DatasetKind;
use crate::util::cli::Args;
use crate::util::json::{self, Value};
use anyhow::{Context, Result};

/// Numeric precision / gradient mode of a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Fp32,
    /// NITI int8, ZO sign from float CE (paper column "INT8").
    Int8,
    /// NITI int8, integer-only ZO sign (paper column "INT8*").
    Int8Star,
}

impl Precision {
    pub fn parse(s: &str) -> Result<Precision> {
        match s {
            "fp32" => Ok(Precision::Fp32),
            "int8" => Ok(Precision::Int8),
            "int8*" | "int8star" => Ok(Precision::Int8Star),
            other => anyhow::bail!("unknown precision '{other}' (fp32|int8|int8*)"),
        }
    }

    pub fn grad_mode(&self) -> ZoGradMode {
        match self {
            Precision::Int8Star => ZoGradMode::IntCE,
            _ => ZoGradMode::FloatCE,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Precision::Fp32 => "FP32",
            Precision::Int8 => "INT8",
            Precision::Int8Star => "INT8*",
        }
    }

    /// The canonical CLI/JSON token; `parse(token()) == self`.
    pub fn token(&self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Int8 => "int8",
            Precision::Int8Star => "int8*",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Config {
    pub model: String,
    pub dataset: DatasetKind,
    pub engine: EngineKind,
    pub method: Method,
    pub precision: Precision,
    pub epochs: usize,
    pub batch: usize,
    pub lr: f32,
    pub eps: f32,
    pub g_clip: f32,
    pub r_max: i8,
    pub b_zo: u32,
    pub seed: u64,
    /// Evaluate every N epochs (the last epoch always evaluates).
    pub eval_every: usize,
    pub train_n: usize,
    pub test_n: usize,
    pub npoints: usize,
    pub ncls: usize,
    pub artifacts_dir: Option<String>,
    pub load_checkpoint: Option<String>,
    pub save_checkpoint: Option<String>,
    /// Resume a run from a v2 checkpoint's training state: restores
    /// params AND loop position (epoch, ZO stream, eval carry), unlike
    /// `load_checkpoint` which only warm-starts the params.
    pub resume: Option<String>,
    /// Cadence of mid-run snapshots to `save_checkpoint`, in epochs
    /// (0 = final save only). Defaults to every epoch, so a killed or
    /// cancelled run keeps its last completed epoch on disk.
    pub ckpt_every: usize,
    /// Snapshot generations kept (>= 1): `path`, `path.1`, ….
    pub ckpt_keep: usize,
    pub verbose: bool,
    /// Use the chunked/parallel ZO kernels for the hot path (default
    /// true). `false` forces the scalar reference — bit-identical, just
    /// slower; useful for parity debugging.
    pub kernels: bool,
    /// Structured perturbation block size in elements (0 = off).
    /// Requires kernels, precision=fp32 and a ZO method; intentionally
    /// changes the trajectory.
    pub sparse_block: usize,
    /// Fraction of perturbation blocks kept when `sparse_block > 0`.
    pub sparse_keep: f32,
    /// Data-parallel replicas (0 = off). With N >= 1 the run becomes a
    /// seed-compressed dp run: each global batch is split into N
    /// strided shards, loss deltas are aggregated per step, and the
    /// identical update is applied everywhere from the shared RNG
    /// stream. Requires method=full-zo, precision=fp32, engine=native.
    pub dp_replicas: usize,
    /// How per-shard loss deltas combine into the committed gradient.
    pub dp_aggregate: DpAggregate,
    /// Smallest surviving quorum allowed to absorb a lost replica's
    /// shard and keep the step barrier moving (1..=dp_replicas).
    pub dp_min_replicas: usize,
    /// ZO/BP boundary mode: `None` = fixed at `method`'s depth,
    /// `Some` = elastic within `[min, max]`, moved at epoch granularity
    /// by the plateau controller. Requires a `bp-tail=<k>` method.
    pub boundary: Option<ElasticSpec>,
    /// Override of the elastic controller's plateau patience (epochs).
    pub elastic_patience: Option<usize>,
    /// Override of the elastic controller's plateau epsilon.
    pub elastic_eps: Option<f32>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            model: "lenet".into(),
            dataset: DatasetKind::SynthMnist,
            engine: EngineKind::Xla,
            method: Method::CLS1,
            precision: Precision::Fp32,
            epochs: 10,
            batch: 32,
            lr: 1e-3,
            eps: 1e-2,
            g_clip: 5.0,
            r_max: 15,
            b_zo: 1,
            seed: 1,
            eval_every: 1,
            train_n: 2048,
            test_n: 512,
            npoints: 128,
            ncls: 40,
            artifacts_dir: None,
            load_checkpoint: None,
            save_checkpoint: None,
            resume: None,
            ckpt_every: 1,
            ckpt_keep: 1,
            verbose: false,
            kernels: true,
            sparse_block: 0,
            sparse_keep: 1.0,
            dp_replicas: 0,
            dp_aggregate: DpAggregate::Mean,
            dp_min_replicas: 1,
            boundary: None,
            elastic_patience: None,
            elastic_eps: None,
        }
    }
}

impl Config {
    /// Load from a JSON object value (config-file content).
    pub fn apply_json(&mut self, v: &Value) -> Result<()> {
        let obj = v.as_obj().context("config root must be an object")?;
        for (k, val) in obj {
            self.set(k, &scalar_to_string(val)?)?;
        }
        Ok(())
    }

    /// Set a single key from its string form (shared by JSON + CLI).
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        match key {
            "model" => self.model = val.to_string(),
            "dataset" => self.dataset = DatasetKind::parse(val)?,
            "engine" => self.engine = EngineKind::parse(val)?,
            "method" => self.method = Method::parse(val)?,
            "bp-tail" | "bp_tail" => {
                self.method = Method::Tail(val.parse().context("bp_tail")?)
            }
            "boundary" => self.boundary = ElasticSpec::parse_boundary(val)?,
            "elastic-patience" | "elastic_patience" => {
                self.elastic_patience = Some(val.parse().context("elastic_patience")?)
            }
            "elastic-eps" | "elastic_eps" => {
                self.elastic_eps = Some(val.parse().context("elastic_eps")?)
            }
            "precision" => self.precision = Precision::parse(val)?,
            "epochs" => self.epochs = val.parse().context("epochs")?,
            "batch" => self.batch = val.parse().context("batch")?,
            "lr" => self.lr = val.parse().context("lr")?,
            "eps" => self.eps = val.parse().context("eps")?,
            "g-clip" | "g_clip" => self.g_clip = val.parse().context("g_clip")?,
            "r-max" | "r_max" => self.r_max = val.parse().context("r_max")?,
            "b-zo" | "b_zo" => self.b_zo = val.parse().context("b_zo")?,
            "seed" => self.seed = val.parse().context("seed")?,
            "eval-every" | "eval_every" => {
                self.eval_every = val.parse().context("eval_every")?
            }
            "train-n" | "train_n" => self.train_n = val.parse().context("train_n")?,
            "test-n" | "test_n" => self.test_n = val.parse().context("test_n")?,
            "npoints" => self.npoints = val.parse().context("npoints")?,
            "ncls" => self.ncls = val.parse().context("ncls")?,
            "artifacts" | "artifacts_dir" => self.artifacts_dir = Some(val.to_string()),
            "load" | "load_checkpoint" => self.load_checkpoint = Some(val.to_string()),
            "save" | "save_checkpoint" => self.save_checkpoint = Some(val.to_string()),
            "resume" => self.resume = Some(val.to_string()),
            "ckpt-every" | "ckpt_every" => {
                self.ckpt_every = val.parse().context("ckpt_every")?
            }
            "ckpt-keep" | "ckpt_keep" => self.ckpt_keep = val.parse().context("ckpt_keep")?,
            "dp" | "dp-replicas" | "dp_replicas" => {
                self.dp_replicas = val.parse().context("dp_replicas")?
            }
            "dp-aggregate" | "dp_aggregate" => self.dp_aggregate = DpAggregate::parse(val)?,
            "dp-min-replicas" | "dp_min_replicas" => {
                self.dp_min_replicas = val.parse().context("dp_min_replicas")?
            }
            "verbose" => self.verbose = val == "true" || val == "1",
            "kernels" => {
                self.kernels = match val {
                    "true" | "1" => true,
                    "false" | "0" => false,
                    other => anyhow::bail!("kernels must be a bool, got '{other}'"),
                }
            }
            "sparse-block" | "sparse_block" => {
                self.sparse_block = val.parse().context("sparse_block")?
            }
            "sparse-keep" | "sparse_keep" => {
                self.sparse_keep = val.parse().context("sparse_keep")?
            }
            other => anyhow::bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Build from CLI args: `--config file.json` first, then flag overrides.
    pub fn from_args(args: &Args) -> Result<Config> {
        let mut cfg = Config::default();
        if let Some(path) = args.get("config") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading config {path}"))?;
            let v = json::parse(&text).context("parsing config json")?;
            cfg.apply_json(&v)?;
        }
        for (k, v) in &args.options {
            if k != "config" {
                cfg.set(k, v)?;
            }
        }
        if args.flag("verbose") {
            cfg.verbose = true;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.model != "lenet" && self.model != "pointnet" {
            anyhow::bail!("model must be lenet|pointnet, got '{}'", self.model);
        }
        if self.model == "pointnet" && self.precision != Precision::Fp32 {
            anyhow::bail!("INT8 is only implemented for lenet (as in the paper)");
        }
        if self.precision != Precision::Fp32 && self.model != "lenet" {
            anyhow::bail!("INT8 requires model=lenet");
        }
        if self.batch == 0 || self.epochs == 0 {
            anyhow::bail!("batch and epochs must be positive");
        }
        if !(0.0..=1e3).contains(&self.eps) || self.eps <= 0.0 {
            anyhow::bail!("eps must be in (0, 1e3]");
        }
        if self.r_max <= 0 {
            anyhow::bail!("r_max must be positive");
        }
        if !(1..=7).contains(&self.b_zo) {
            anyhow::bail!("b_zo must be in 1..=7");
        }
        if self.eval_every == 0 {
            anyhow::bail!("eval_every must be >= 1");
        }
        if self.ckpt_keep == 0 {
            anyhow::bail!("ckpt_keep must be >= 1");
        }
        if self.resume.is_some() && self.load_checkpoint.is_some() {
            anyhow::bail!(
                "--resume restores params AND loop state; it cannot be combined with --load"
            );
        }
        if self.sparse_block > 0 {
            if !self.kernels {
                anyhow::bail!("sparse_block requires the kernel path (kernels=true)");
            }
            if self.precision != Precision::Fp32 {
                anyhow::bail!(
                    "sparse_block is fp32-only (the int8 path has its own p_zero sparsity)"
                );
            }
            if self.method == Method::FullBp {
                anyhow::bail!("sparse_block requires a ZO method (full-bp has no perturbation)");
            }
            if self.dp_replicas > 0 {
                anyhow::bail!("sparse_block is not supported for dp runs");
            }
            if !(self.sparse_keep > 0.0 && self.sparse_keep <= 1.0) {
                anyhow::bail!("sparse_keep must be in (0, 1]");
            }
        }
        if let Some(k) = self.method.bp_tail() {
            let max = self.model_enum().max_bp_tail();
            if k > max {
                anyhow::bail!(
                    "bp-tail={k} exceeds model {}'s classifier stack (max bp-tail={max})",
                    self.model
                );
            }
            if self.engine == EngineKind::Xla && k > 2 {
                anyhow::bail!("bp-tail>2 requires engine=native (the XLA graphs stop at cls2)");
            }
        }
        if let Some(es) = self.effective_elastic()? {
            if self.method.bp_tail().is_none() {
                anyhow::bail!(
                    "an elastic boundary requires a bp-tail method, not '{}'",
                    self.method.token()
                );
            }
            let max = self.model_enum().max_bp_tail();
            if es.max > max {
                anyhow::bail!(
                    "elastic boundary max bp-tail={} exceeds model {}'s classifier stack (max {max})",
                    es.max,
                    self.model
                );
            }
            if self.engine == EngineKind::Xla && es.max > 2 {
                anyhow::bail!(
                    "elastic max bp-tail>2 requires engine=native (the XLA graphs stop at cls2)"
                );
            }
            let k0 = self.method.bp_tail().unwrap_or(0);
            if !(es.min..=es.max).contains(&k0) {
                anyhow::bail!(
                    "method bp-tail={k0} starts outside the elastic range {}..={}",
                    es.min,
                    es.max
                );
            }
            if self.dp_replicas > 0 {
                anyhow::bail!(
                    "dp runs cannot move the ZO/BP boundary (the wire carries loss deltas \
                     only); use boundary=fixed"
                );
            }
        } else if self.elastic_patience.is_some() || self.elastic_eps.is_some() {
            anyhow::bail!("elastic_patience/elastic_eps require boundary=elastic:<min>-<max>");
        }
        if self.dp_replicas > 0 {
            if self.method != Method::FULL_ZO {
                anyhow::bail!(
                    "dp requires method=full-zo: replicas replay the shared RNG stream over \
                     the whole net, so a nonzero bp tail (method '{}') would silently \
                     diverge — the wire carries loss deltas only",
                    self.method.token()
                );
            }
            if self.precision != Precision::Fp32 {
                anyhow::bail!("dp requires precision=fp32");
            }
            if self.engine != EngineKind::Native {
                anyhow::bail!("dp requires engine=native (shard micro-batches vary in size)");
            }
            if self.resume.is_some() || self.load_checkpoint.is_some() {
                anyhow::bail!("dp runs always start from scratch (no --resume / --load)");
            }
            if self.dp_replicas > crate::coordinator::DP_MAX_REPLICAS {
                anyhow::bail!(
                    "dp replicas must be <= {}",
                    crate::coordinator::DP_MAX_REPLICAS
                );
            }
            if self.batch < self.dp_replicas {
                anyhow::bail!("dp needs batch >= replicas so every shard owns >= 1 row");
            }
            if self.dp_min_replicas == 0 || self.dp_min_replicas > self.dp_replicas {
                anyhow::bail!("dp_min_replicas must be in 1..=dp_replicas");
            }
        }
        Ok(())
    }

    /// The elastic boundary spec with patience/eps overrides applied
    /// (`None` when the boundary is fixed).
    pub fn effective_elastic(&self) -> Result<Option<ElasticSpec>> {
        let Some(mut es) = self.boundary else { return Ok(None) };
        if let Some(p) = self.elastic_patience {
            anyhow::ensure!(p >= 1, "elastic_patience must be >= 1");
            es.patience = p;
        }
        if let Some(e) = self.elastic_eps {
            anyhow::ensure!(e >= 0.0, "elastic_eps must be >= 0");
            es.eps = e;
        }
        Ok(Some(es))
    }

    /// The dp mode of this run, if enabled.
    pub fn dp_spec(&self) -> Option<DpSpec> {
        (self.dp_replicas > 0).then_some(DpSpec {
            replicas: self.dp_replicas,
            aggregate: self.dp_aggregate,
            min_replicas: self.dp_min_replicas,
        })
    }

    /// The unified training-run description (precision-agnostic session
    /// API): everything `coordinator::session::run` needs, with the
    /// stop flag / progress sink left at their no-op defaults for the
    /// caller to arm.
    pub fn train_spec(&self) -> TrainSpec {
        TrainSpec {
            method: self.method,
            precision: match self.precision {
                Precision::Fp32 => PrecisionSpec::Fp32,
                p => PrecisionSpec::Int8 {
                    grad_mode: p.grad_mode(),
                    r_max: self.r_max,
                    b_zo: self.b_zo,
                },
            },
            epochs: self.epochs,
            batch: self.batch,
            lr0: self.lr,
            eps: self.eps,
            g_clip: self.g_clip,
            seed: self.seed,
            eval_every: self.eval_every,
            verbose: self.verbose,
            kernels: self.kernels,
            sparse_block: self.sparse_block,
            sparse_keep: self.sparse_keep,
            elastic: self.effective_elastic().expect("validated config"),
            checkpoint: self
                .save_checkpoint
                .as_ref()
                .filter(|_| self.ckpt_every > 0)
                .map(|path| CheckpointPolicy {
                    path: path.clone(),
                    every_n_epochs: self.ckpt_every,
                    keep_last: self.ckpt_keep,
                }),
            ..TrainSpec::default()
        }
    }

    pub fn model_enum(&self) -> crate::coordinator::Model {
        match self.model.as_str() {
            "lenet" => crate::coordinator::Model::LeNet,
            _ => crate::coordinator::Model::PointNet { npoints: self.npoints, ncls: self.ncls },
        }
    }
}

/// Canonical JSON-scalar → config-string coercion, shared by config
/// files and `serve` job specs (both feed [`Config::set`]).
pub fn scalar_to_string(v: &Value) -> Result<String> {
    Ok(match v {
        Value::Str(s) => s.clone(),
        Value::Num(n) => {
            if n.fract() == 0.0 {
                format!("{}", *n as i64)
            } else {
                format!("{n}")
            }
        }
        Value::Bool(b) => b.to_string(),
        other => anyhow::bail!("config values must be scalars, got {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(a: &[&str]) -> Args {
        Args::parse(a.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn cli_overrides() {
        let cfg = Config::from_args(&args(&[
            "--model", "pointnet", "--method", "full-zo", "--epochs", "3",
            "--lr", "0.005", "--engine", "native", "--verbose",
        ]))
        .unwrap();
        assert_eq!(cfg.model, "pointnet");
        assert_eq!(cfg.method, Method::FULL_ZO);
        assert_eq!(cfg.epochs, 3);
        assert!((cfg.lr - 0.005).abs() < 1e-9);
        assert_eq!(cfg.engine, EngineKind::Native);
        assert!(cfg.verbose);
    }

    #[test]
    fn json_config_applies() {
        let mut cfg = Config::default();
        let v = json::parse(
            r#"{"model": "lenet", "precision": "int8*", "epochs": 7, "batch": 64}"#,
        )
        .unwrap();
        cfg.apply_json(&v).unwrap();
        assert_eq!(cfg.precision, Precision::Int8Star);
        assert_eq!(cfg.precision.grad_mode(), ZoGradMode::IntCE);
        assert_eq!(cfg.epochs, 7);
        assert_eq!(cfg.batch, 64);
    }

    #[test]
    fn invalid_combo_rejected() {
        let r = Config::from_args(&args(&["--model", "pointnet", "--precision", "int8"]));
        assert!(r.is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        let r = Config::from_args(&args(&["--optimzer", "adam"]));
        assert!(r.is_err());
    }

    #[test]
    fn precision_labels() {
        assert_eq!(Precision::Int8Star.label(), "INT8*");
        assert!(Precision::parse("bf16").is_err());
    }

    #[test]
    fn precision_tokens_roundtrip() {
        for p in [Precision::Fp32, Precision::Int8, Precision::Int8Star] {
            assert_eq!(Precision::parse(p.token()).unwrap(), p);
        }
    }

    #[test]
    fn train_spec_carries_precision_and_knobs() {
        let mut cfg = Config::default();
        cfg.set("precision", "int8*").unwrap();
        cfg.set("r_max", "31").unwrap();
        cfg.set("eval_every", "3").unwrap();
        cfg.validate().unwrap();
        let spec = cfg.train_spec();
        assert_eq!(
            spec.precision,
            PrecisionSpec::Int8 { grad_mode: ZoGradMode::IntCE, r_max: 31, b_zo: 1 }
        );
        assert_eq!(spec.eval_every, 3);
        assert_eq!(spec.label(), "ZO-Feat-Cls1 INT8*");

        cfg.set("precision", "fp32").unwrap();
        assert_eq!(cfg.train_spec().precision, PrecisionSpec::Fp32);
    }

    #[test]
    fn kernel_flags_parse_and_flow_to_spec() {
        let cfg = Config::from_args(&args(&[
            "--method", "full-zo", "--kernels", "false",
        ]))
        .unwrap();
        assert!(!cfg.kernels);
        assert!(!cfg.train_spec().kernels);

        let cfg = Config::from_args(&args(&[
            "--method", "full-zo", "--sparse-block", "64", "--sparse-keep", "0.25",
        ]))
        .unwrap();
        let spec = cfg.train_spec();
        assert_eq!(spec.sparse_block, 64);
        assert!((spec.sparse_keep - 0.25).abs() < 1e-9);
    }

    #[test]
    fn bad_sparse_combos_rejected() {
        // scalar path cannot mask
        assert!(Config::from_args(&args(&[
            "--method", "full-zo", "--sparse-block", "64", "--kernels", "false",
        ]))
        .is_err());
        // int8 has its own sparsity
        assert!(Config::from_args(&args(&[
            "--method", "full-zo", "--precision", "int8", "--sparse-block", "64",
        ]))
        .is_err());
        // full-bp has no perturbation to mask
        assert!(Config::from_args(&args(&[
            "--method", "full-bp", "--sparse-block", "64",
        ]))
        .is_err());
        // dp commit log assumes dense z
        assert!(Config::from_args(&args(&[
            "--method", "full-zo", "--engine", "native", "--dp", "2",
            "--sparse-block", "64",
        ]))
        .is_err());
        // keep out of range
        assert!(Config::from_args(&args(&[
            "--method", "full-zo", "--sparse-block", "64", "--sparse-keep", "0",
        ]))
        .is_err());
        // bad bool
        assert!(Config::from_args(&args(&["--kernels", "maybe"])).is_err());
    }

    #[test]
    fn eval_every_zero_rejected() {
        let mut cfg = Config::default();
        cfg.set("eval_every", "0").unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn checkpoint_policy_arms_with_save_path() {
        let mut cfg = Config::default();
        assert_eq!(cfg.train_spec().checkpoint, None, "no save path, no policy");
        cfg.set("save", "/tmp/run.ckpt").unwrap();
        cfg.set("ckpt_every", "2").unwrap();
        cfg.set("ckpt-keep", "3").unwrap();
        cfg.validate().unwrap();
        assert_eq!(
            cfg.train_spec().checkpoint,
            Some(CheckpointPolicy {
                path: "/tmp/run.ckpt".into(),
                every_n_epochs: 2,
                keep_last: 3,
            })
        );
        // cadence 0 = final-save-only: the mid-run policy disarms
        cfg.set("ckpt_every", "0").unwrap();
        assert_eq!(cfg.train_spec().checkpoint, None);
    }

    #[test]
    fn dp_flags_parse_and_validate() {
        let cfg = Config::from_args(&args(&[
            "--method", "full-zo", "--engine", "native", "--dp", "4",
            "--dp-aggregate", "sum", "--dp-min-replicas", "2",
        ]))
        .unwrap();
        assert_eq!(
            cfg.dp_spec(),
            Some(DpSpec { replicas: 4, aggregate: DpAggregate::Sum, min_replicas: 2 })
        );
        assert_eq!(Config::default().dp_spec(), None);
    }

    #[test]
    fn dp_invalid_combos_rejected() {
        // wrong method
        assert!(Config::from_args(&args(&["--engine", "native", "--dp", "2"])).is_err());
        // wrong engine (default xla)
        assert!(Config::from_args(&args(&["--method", "full-zo", "--dp", "2"])).is_err());
        // int8
        assert!(Config::from_args(&args(&[
            "--method", "full-zo", "--engine", "native", "--precision", "int8", "--dp", "2",
        ]))
        .is_err());
        // resume
        assert!(Config::from_args(&args(&[
            "--method", "full-zo", "--engine", "native", "--dp", "2", "--resume", "/tmp/x",
        ]))
        .is_err());
        // quorum out of range
        assert!(Config::from_args(&args(&[
            "--method", "full-zo", "--engine", "native", "--dp", "2",
            "--dp-min-replicas", "3",
        ]))
        .is_err());
        // batch smaller than replica count
        assert!(Config::from_args(&args(&[
            "--method", "full-zo", "--engine", "native", "--dp", "64", "--batch", "32",
        ]))
        .is_err());
    }

    #[test]
    fn bp_tail_key_sets_method() {
        let cfg = Config::from_args(&args(&["--engine", "native", "--bp-tail", "3"])).unwrap();
        assert_eq!(cfg.method, Method::Tail(3));
        assert_eq!(cfg.method.token(), "bp-tail=3");
        // the preset spellings stay exact aliases
        let cfg = Config::from_args(&args(&["--bp-tail", "2"])).unwrap();
        assert_eq!(cfg.method, Method::CLS1);
        assert_eq!(cfg.method.token(), "cls1");
    }

    #[test]
    fn bp_tail_bounds_enforced() {
        // deeper than the classifier stack
        assert!(Config::from_args(&args(&["--engine", "native", "--bp-tail", "4"])).is_err());
        // XLA graphs stop at cls2
        assert!(Config::from_args(&args(&["--engine", "xla", "--bp-tail", "3"])).is_err());
    }

    #[test]
    fn elastic_boundary_parses_and_flows_to_spec() {
        let cfg = Config::from_args(&args(&[
            "--engine", "native", "--bp-tail", "1", "--boundary", "elastic:0-3",
            "--elastic-patience", "3", "--elastic-eps", "0.01",
        ]))
        .unwrap();
        let es = cfg.train_spec().elastic.unwrap();
        assert_eq!((es.min, es.max, es.patience), (0, 3, 3));
        assert!((es.eps - 0.01).abs() < 1e-9);
        // boundary=fixed is the explicit spelling of the default
        let cfg = Config::from_args(&args(&["--boundary", "fixed"])).unwrap();
        assert_eq!(cfg.train_spec().elastic, None);
    }

    #[test]
    fn elastic_invalid_combos_rejected() {
        // full-bp has no movable boundary
        assert!(Config::from_args(&args(&[
            "--method", "full-bp", "--boundary", "elastic:0-2",
        ]))
        .is_err());
        // range exceeds the model's classifier stack
        assert!(Config::from_args(&args(&[
            "--engine", "native", "--boundary", "elastic:0-4",
        ]))
        .is_err());
        // xla caps the range at cls2
        assert!(Config::from_args(&args(&["--boundary", "elastic:0-3"])).is_err());
        // method starts outside the range
        assert!(Config::from_args(&args(&[
            "--method", "full-zo", "--boundary", "elastic:1-2",
        ]))
        .is_err());
        // dp replays the stream over the whole net
        assert!(Config::from_args(&args(&[
            "--method", "full-zo", "--engine", "native", "--dp", "2",
            "--boundary", "elastic:0-2",
        ]))
        .is_err());
        // orphan knobs without an elastic boundary
        assert!(Config::from_args(&args(&["--elastic-patience", "3"])).is_err());
    }

    #[test]
    fn resume_excludes_load_and_bad_keep_rejected() {
        let mut cfg = Config::default();
        cfg.set("resume", "/tmp/a.ckpt").unwrap();
        cfg.validate().unwrap();
        cfg.set("load", "/tmp/b.ckpt").unwrap();
        assert!(cfg.validate().is_err());

        let mut cfg = Config::default();
        cfg.set("ckpt_keep", "0").unwrap();
        assert!(cfg.validate().is_err());
    }
}
