//! # ElasticZO — memory-efficient on-device learning (paper reproduction)
//!
//! Rust implementation of *“ElasticZO: A Memory-Efficient On-Device
//! Learning with Combined Zeroth- and First-Order Optimization”*
//! (Sugiura & Matsutani, 2025), structured as the three-layer
//! rust + JAX + Pallas stack described in `DESIGN.md`:
//!
//! * **L3 (this crate)** — the on-device-learning coordinator: dataset
//!   pipeline, the seed-trick ZO engine (perturb / restore / update in
//!   place), elastic ZO/BP partitioning, NITI INT8 training, schedules,
//!   metrics, checkpoints, the analytic memory model (paper Eqs. 2–5 and
//!   13–15) and per-phase telemetry (paper Fig. 7).
//! * **L2/L1 (python, build-time only)** — JAX models calling Pallas
//!   kernels, AOT-lowered to `artifacts/*.hlo.txt`; loaded and executed
//!   here through the PJRT C API (`runtime`), never touching python at
//!   training time.
//!
//! Two interchangeable execution engines mirror the paper's two
//! implementations (PyTorch for accuracy, C++/NEON for on-device cost):
//! the **XLA engine** (`coordinator::xla_engine`, behind the
//! off-by-default `xla` cargo feature) runs the AOT artifacts, and the
//! **native engine** ([`nn`], [`int8`]) is a pure-rust reference —
//! including the paper's integer-only INT8* path.
//!
//! On top of the trainers sits [`serve`]: a std-only multi-job training
//! server (`repro serve`) that queues, schedules, observes and cancels
//! jobs across a worker pool — and, in cluster mode, across a fleet of
//! remote worker agents (`repro agent`) with lease-based failover —
//! over an HTTP/1.1 + JSON control plane; see the [`serve`] module
//! docs for the protocol. The [`metrics`] registry exposes the whole
//! stack — request latencies, per-phase training histograms, live
//! heap accounting — in Prometheus text format at `GET /metrics`.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod int8;
pub mod launch;
pub mod memory;
pub mod metrics;
pub mod nn;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod tensor;
pub mod util;
