//! SSE stress for the reactor connection plane: 512 concurrent
//! firehose subscribers all see the identical event sequence during a
//! live job (the pre-reactor server refused anything past 64 streams);
//! a slow reader is shed with an explicit `lagged` frame instead of
//! ever blocking the trainer; and a mass disconnect tears every
//! registration down (`repro_sse_streams_active` returns to 0).

use elasticzo::serve::{request, ServeOptions, Server};
use elasticzo::util::json;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// The metrics registry is process-global, so tests that assert on
/// gauge values (and tests that open hundreds of sockets) run one at
/// a time.
static SERIAL: Mutex<()> = Mutex::new(());

fn boot(opts: ServeOptions) -> (String, std::thread::JoinHandle<()>) {
    let server = Server::bind(&opts).expect("bind");
    let addr = server.local_addr().expect("addr").to_string();
    let h = std::thread::spawn(move || server.run().expect("server run"));
    (addr, h)
}

fn tiny_spec(seed: usize, epochs: usize) -> json::Value {
    json::parse(&format!(
        r#"{{"method": "cls1", "precision": "fp32", "engine": "native",
            "epochs": {epochs}, "batch": 16, "train_n": 64, "test_n": 32, "seed": {seed}}}"#
    ))
    .expect("spec")
}

/// Open a firehose stream and read through the SSE response header;
/// returns the socket plus any frame bytes that arrived with it.
fn open_stream(addr: &str) -> (TcpStream, Vec<u8>) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(15))).expect("timeout");
    s.write_all(b"GET /events HTTP/1.1\r\n\r\n").expect("write");
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 1024];
    loop {
        if let Some(he) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            let head = String::from_utf8_lossy(&buf[..he]).to_string();
            assert!(head.contains("text/event-stream"), "SSE header: {head}");
            let rest = buf.split_off(he + 4);
            return (s, rest);
        }
        let n = s.read(&mut tmp).expect("read SSE header");
        assert!(n > 0, "stream closed before the SSE header");
        buf.extend_from_slice(&tmp[..n]);
    }
}

/// Read until `marker` is present and the buffer ends on a frame
/// boundary, then return the comment-stripped frames up to and
/// including the one carrying the marker.
fn read_frames_until(s: &mut TcpStream, buf: &mut Vec<u8>, marker: &str) -> Vec<String> {
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut tmp = [0u8; 4096];
    loop {
        let text = String::from_utf8_lossy(buf).to_string();
        if text.contains(marker) && buf.ends_with(b"\n\n") {
            let mut frames = Vec::new();
            for block in text.split("\n\n") {
                if block.is_empty() || block.starts_with(':') {
                    continue; // keep-alive comments are timing noise
                }
                frames.push(block.to_string());
                if block.contains(marker) {
                    return frames;
                }
            }
        }
        assert!(Instant::now() < deadline, "no '{marker}' frame within 30s; got: {text}");
        let n = s.read(&mut tmp).expect("read frames");
        assert!(n > 0, "stream closed before '{marker}' arrived");
        buf.extend_from_slice(&tmp[..n]);
    }
}

fn poll_stats_until(addr: &str, key: &str, want: usize, secs: u64) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    loop {
        let (_, s) = request(addr, "GET", "/stats", None).expect("stats");
        if s.get(key).as_usize() == Some(want) {
            return;
        }
        assert!(Instant::now() < deadline, "{key} never reached {want}: {}", json::to_string(&s));
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn firehose_512_subscribers_see_identical_event_sequence() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    const STREAMS: usize = 512;
    let (addr, h) =
        boot(ServeOptions { port: 0, workers: 1, queue_cap: 8, ..Default::default() });

    // all subscribers registered before the job exists, so every one
    // is entitled to the full sequence
    let mut streams = Vec::with_capacity(STREAMS);
    for _ in 0..STREAMS {
        streams.push(open_stream(&addr));
    }

    let (status, v) = request(&addr, "POST", "/jobs", Some(&tiny_spec(1, 1))).expect("submit");
    assert_eq!(status, 200, "submit: {}", json::to_string(&v));
    poll_stats_until(&addr, "jobs_done", 1, 60);

    let mut reference: Option<Vec<String>> = None;
    for (i, (s, buf)) in streams.iter_mut().enumerate() {
        let frames = read_frames_until(s, buf, "\"state\":\"done\"");
        assert!(
            frames.len() >= 3,
            "stream {i} saw only {} frames: {frames:?}",
            frames.len()
        );
        match &reference {
            None => reference = Some(frames),
            Some(r) => assert_eq!(&frames, r, "stream {i} diverged from stream 0"),
        }
    }

    drop(streams);
    request(&addr, "POST", "/shutdown", None).expect("shutdown");
    h.join().unwrap();
}

#[test]
fn slow_reader_is_shed_with_lagged_and_never_blocks_the_trainer() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // subscriber buffers of exactly one event: any publish burst the
    // reactor cannot drain between two events sheds the stream
    let (addr, h) = boot(ServeOptions {
        port: 0,
        workers: 1,
        queue_cap: 8,
        events_buffer: 1,
        ..Default::default()
    });

    let (mut slow, mut slow_buf) = open_stream(&addr);

    // occupy the single worker so follow-up jobs stay queued
    let (status, v) =
        request(&addr, "POST", "/jobs", Some(&tiny_spec(1, 10))).expect("submit long job");
    assert_eq!(status, 200, "submit: {}", json::to_string(&v));
    let id_a = v.get("id").as_usize().expect("job id") as u64;

    // pipeline submit+cancel pairs in a single TCP segment: the
    // reactor thread serving them publishes queued/cancelled bursts
    // back-to-back, far faster than any subscriber pump can drain a
    // one-event buffer — deterministic shedding, while the slow
    // client reads nothing
    let spec_b = json::to_string(&tiny_spec(2, 1));
    let spec_c = json::to_string(&tiny_spec(3, 1));
    let mut wire = Vec::new();
    for (spec, id) in [(&spec_b, id_a + 1), (&spec_c, id_a + 2)] {
        wire.extend_from_slice(
            format!("POST /jobs HTTP/1.1\r\nContent-Length: {}\r\n\r\n{spec}", spec.len())
                .as_bytes(),
        );
        wire.extend_from_slice(format!("POST /jobs/{id}/cancel HTTP/1.1\r\n\r\n").as_bytes());
    }
    let mut burst = TcpStream::connect(&addr).expect("connect");
    burst.set_read_timeout(Some(Duration::from_secs(10))).expect("timeout");
    burst.write_all(&wire).expect("pipelined burst");
    // four 200s, in order
    let mut raw = Vec::new();
    let mut tmp = [0u8; 4096];
    while raw.windows(4).filter(|w| w == b"\r\n\r\n").count() < 4 {
        let n = burst.read(&mut tmp).expect("burst responses");
        if n == 0 {
            break;
        }
        raw.extend_from_slice(&tmp[..n]);
    }
    let text = String::from_utf8_lossy(&raw);
    assert_eq!(
        text.matches("HTTP/1.1 200").count(),
        4,
        "submit+cancel pipeline answered in order: {text}"
    );

    // the stalled subscriber now catches up onto an explicit lagged
    // marker instead of a silently incomplete sequence
    let frames = read_frames_until(&mut slow, &mut slow_buf, "event: lagged");
    let lagged = frames.last().expect("frames nonempty");
    assert!(lagged.contains("\"type\":\"lagged\""), "resync payload: {lagged}");
    assert!(lagged.contains("next_seq"), "resync payload names a seq: {lagged}");

    // the trainer side never blocked on the slow stream: the long job
    // is still cancellable and the server still drains promptly
    let (status, _) = request(&addr, "POST", &format!("/jobs/{id_a}/cancel"), None).unwrap();
    assert_eq!(status, 200);
    let t0 = Instant::now();
    request(&addr, "POST", "/shutdown", None).expect("shutdown");
    drop(slow);
    drop(burst);
    h.join().unwrap();
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "drain stalled behind a shed subscriber: {:?}",
        t0.elapsed()
    );
}

#[test]
fn mass_disconnect_leaves_no_sse_registrations_behind() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    const STREAMS: usize = 64;
    let (addr, h) =
        boot(ServeOptions { port: 0, workers: 1, queue_cap: 8, ..Default::default() });

    // raw-socket scrape: /metrics is the one non-JSON route
    let gauge = |addr: &str| -> f64 {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(10))).expect("timeout");
        s.write_all(b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n").expect("write");
        let mut raw = Vec::new();
        s.read_to_end(&mut raw).expect("scrape");
        String::from_utf8_lossy(&raw)
            .lines()
            .find(|l| l.starts_with("repro_sse_streams_active"))
            .and_then(|l| l.split_whitespace().last())
            .and_then(|v| v.parse().ok())
            .expect("repro_sse_streams_active exported")
    };

    let mut streams = Vec::with_capacity(STREAMS);
    for _ in 0..STREAMS {
        streams.push(open_stream(&addr));
    }
    assert_eq!(gauge(&addr), STREAMS as f64, "every stream registered");

    // hang up all at once; the reactors notice EOF and unregister
    drop(streams);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let open = gauge(&addr);
        if open == 0.0 {
            break;
        }
        assert!(Instant::now() < deadline, "{open} SSE registrations leaked after disconnect");
        std::thread::sleep(Duration::from_millis(20));
    }

    request(&addr, "POST", "/shutdown", None).expect("shutdown");
    h.join().unwrap();
}
