//! Cluster e2e: a `--cluster` coordinator with NO local workers fans
//! queued jobs out to remote worker agents over the HTTP/JSON control
//! plane, and survives an agent dying mid-job — the lease reaper
//! requeues the job from its last checkpoint and it completes on the
//! surviving agent with bit-identical resume semantics (verified
//! against an uninterrupted single-process run, the same parity
//! machinery as `tests/checkpoint_resume.rs`).

use elasticzo::coordinator::checkpoint;
use elasticzo::coordinator::control::{ProgressSink, StopFlag};
use elasticzo::launch;
use elasticzo::serve::{
    request, Agent, AgentHandle, AgentOptions, ClusterOptions, ServeOptions, Server,
};
use elasticzo::util::json::Value;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

const LONG: Duration = Duration::from_secs(300);

fn start_coordinator(lease_ms: u64) -> (String, JoinHandle<()>) {
    let server = Server::bind(&ServeOptions {
        port: 0,
        workers: 0, // pure coordinator: every job must run on an agent
        queue_cap: 8,
        journal: None,
        cluster: Some(ClusterOptions { lease_ms }),
        ..Default::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || server.run().unwrap());
    (addr, h)
}

fn spawn_agent(addr: &str, name: &str) -> AgentHandle {
    Agent::spawn(AgentOptions {
        coordinator: addr.to_string(),
        capacity: 1,
        name: name.to_string(),
        poll_ms: 50,
        max_poll_failures: 40,
        mem_budget: None,
    })
    .unwrap()
}

fn shutdown(addr: &str, handle: JoinHandle<()>) {
    let (status, _) = request(addr, "POST", "/shutdown", None).unwrap();
    assert_eq!(status, 200);
    handle.join().unwrap();
}

fn submit(addr: &str, spec: &str) -> u64 {
    let body = elasticzo::util::json::parse(spec).unwrap();
    let (status, v) = request(addr, "POST", "/jobs", Some(&body)).unwrap();
    assert_eq!(status, 200, "submit failed: {}", elasticzo::util::json::to_string(&v));
    v.get("id").as_f64().unwrap() as u64
}

fn get_job(addr: &str, id: u64) -> Value {
    let (status, v) = request(addr, "GET", &format!("/jobs/{id}"), None).unwrap();
    assert_eq!(status, 200, "job {id} must exist");
    v
}

fn poll_until(addr: &str, id: u64, pred: impl Fn(&Value) -> bool, what: &str) -> Value {
    let t0 = Instant::now();
    loop {
        let v = get_job(addr, id);
        if pred(&v) {
            return v;
        }
        assert!(
            t0.elapsed() < LONG,
            "timed out waiting for {what} on job {id}; last: {}",
            elasticzo::util::json::to_string(&v)
        );
        std::thread::sleep(Duration::from_millis(30));
    }
}

#[test]
fn jobs_fan_out_across_two_agents() {
    let (addr, h) = start_coordinator(10_000);
    let a1 = spawn_agent(&addr, "edge-1");
    let a2 = spawn_agent(&addr, "edge-2");

    // both agents are visible on the control plane
    let (status, v) = request(&addr, "GET", "/cluster/agents", None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(v.get("agents").as_arr().unwrap().len(), 2);
    let (_, s) = request(&addr, "GET", "/stats", None).unwrap();
    assert_eq!(s.get("agents").as_usize(), Some(2));

    // two jobs, two capacity-1 agents: one each (there are no local
    // workers, so remote execution is the only way these can finish).
    // Sized so neither job can finish within a poll interval — the
    // first agent is still busy when the second one pulls job 2.
    let spec = r#"{"method": "cls1", "precision": "fp32", "engine": "native",
                   "epochs": 3, "batch": 32, "train_n": 384, "test_n": 96, "seed": 7}"#;
    let j1 = submit(&addr, spec);
    let j2 = submit(&addr, spec);

    let v1 = poll_until(&addr, j1, |v| v.get("state").as_str() == Some("done"), "job 1 done");
    let v2 = poll_until(&addr, j2, |v| v.get("state").as_str() == Some("done"), "job 2 done");
    for (v, label) in [(&v1, "j1"), (&v2, "j2")] {
        assert_eq!(v.get("history").as_arr().unwrap().len(), 3, "{label} history");
        assert!(v.get("best_test_acc").as_f64().unwrap() > 0.0, "{label} accuracy");
    }
    let ag1 = v1.get("agent").as_usize().expect("job 1 ran on an agent") as u64;
    let ag2 = v2.get("agent").as_usize().expect("job 2 ran on an agent") as u64;
    assert_ne!(ag1, ag2, "capacity-1 agents must each take one job");
    let mut got = [ag1, ag2];
    got.sort_unstable();
    let mut want = [a1.id(), a2.id()];
    want.sort_unstable();
    assert_eq!(got, want, "the work went to the registered agents");

    a1.stop();
    a2.stop();
    shutdown(&addr, h);
}

#[test]
fn mem_budget_negotiates_a_shallower_boundary() {
    let (addr, h) = start_coordinator(10_000);
    // an elastic job: the method starts at the floor, and assignment
    // pins the deepest BP tail the assigned agent's budget affords
    let spec = r#"{"method": "full-zo", "boundary": "elastic:0-2", "precision": "fp32",
                   "engine": "native", "epochs": 1, "batch": 16,
                   "train_n": 64, "test_n": 32, "seed": 7}"#;

    // phase 1: only a tight-budget agent is up — 1 byte fits no
    // candidate, so negotiation falls back to the elastic floor k=0
    let tight = Agent::spawn(AgentOptions {
        coordinator: addr.to_string(),
        capacity: 1,
        name: "tight".to_string(),
        poll_ms: 50,
        max_poll_failures: 40,
        mem_budget: Some(1),
    })
    .unwrap();
    let j1 = submit(&addr, spec);
    let v1 = poll_until(&addr, j1, |v| v.get("state").as_str() == Some("done"), "tight done");
    assert_eq!(v1.get("agent").as_usize(), Some(tight.id() as usize));
    tight.stop();

    // phase 2: an unconstrained agent gets the SAME spec pinned to the
    // elastic ceiling k=2 at assignment
    let free = spawn_agent(&addr, "unconstrained");
    let j2 = submit(&addr, spec);
    let v2 =
        poll_until(&addr, j2, |v| v.get("state").as_str() == Some("done"), "unconstrained done");
    assert_eq!(v2.get("agent").as_usize(), Some(free.id() as usize));
    free.stop();

    // the boundary each run actually trained under, from the per-epoch
    // audit trail
    let k_of = |v: &Value| {
        v.get("history")
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.get("bp_tail").as_usize().expect("elastic epochs record bp_tail"))
            .max()
            .unwrap()
    };
    let (k1, k2) = (k_of(&v1), k_of(&v2));
    assert_eq!(k1, 0, "tight budget must pin the elastic floor");
    assert_eq!(k2, 2, "unconstrained agent must get the deepest tail");
    assert!(k1 < k2, "budgeted agent must train at a shallower boundary");
    // the negotiated pin lands in the job's effective spec (Tail(2)
    // serializes as its legacy alias), so failover/resume and journal
    // replay reproduce the same boundary
    assert_eq!(v2.get("spec").get("method").as_str(), Some("cls1"));
    assert_eq!(v1.get("spec").get("method").as_str(), Some("full-zo"));

    shutdown(&addr, h);
}

#[test]
fn agent_death_requeues_from_checkpoint_and_completes_elsewhere() {
    let dir = std::env::temp_dir().join(format!("ezo_cluster_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("sharded.ckpt").display().to_string();
    let ckpt_straight = dir.join("straight.ckpt").display().to_string();
    std::fs::remove_file(&ckpt).ok();
    std::fs::remove_file(&ckpt_straight).ok();

    // release-mode epochs are ~2 orders of magnitude faster; keep the
    // job long enough that the kill below lands mid-run
    let epochs: usize = if cfg!(debug_assertions) { 20 } else { 200 };

    // a short lease so failover happens within a couple of seconds
    let (addr, h) = start_coordinator(1_500);
    let doomed = spawn_agent(&addr, "doomed");

    let job = submit(
        &addr,
        &format!(
            r#"{{"name": "sharded", "method": "full-zo", "precision": "fp32",
                "engine": "native", "epochs": {epochs}, "batch": 16,
                "train_n": 64, "test_n": 32, "seed": 5, "save": "{ckpt}"}}"#
        ),
    );

    // let it make real progress (and write cadence snapshots) on the
    // doomed agent, then kill the agent without a goodbye
    let v = poll_until(
        &addr,
        job,
        |v| v.get("epochs_done").as_usize().unwrap_or(0) >= 2,
        "two epochs on the first agent",
    );
    assert_eq!(v.get("agent").as_usize(), Some(doomed.id() as usize));
    let doomed_id = doomed.id();
    doomed.kill();

    // a survivor joins; the lease reaper requeues the job from its
    // last checkpoint and the survivor finishes it
    let survivor = spawn_agent(&addr, "survivor");
    let v = poll_until(
        &addr,
        job,
        |v| v.get("state").as_str() == Some("done"),
        "failover to the survivor",
    );
    assert_eq!(
        v.get("agent").as_usize(),
        Some(survivor.id() as usize),
        "the job must finish on the surviving agent"
    );
    assert_ne!(survivor.id(), doomed_id);
    // the requeued spec carried the resume path back over the wire
    assert_eq!(v.get("spec").get("resume").as_str(), Some(ckpt.as_str()));
    // replayed + resumed epochs form one gapless history
    let history = v.get("history").as_arr().unwrap();
    assert_eq!(history.len(), epochs, "history must cover every epoch exactly once");
    for (i, e) in history.iter().enumerate() {
        assert_eq!(e.get("epoch").as_usize(), Some(i), "history must be the epochs 0..{epochs}");
    }
    // the dead agent was reaped from the listing
    let (_, agents) = request(&addr, "GET", "/cluster/agents", None).unwrap();
    let listed = agents.get("agents").as_arr().unwrap();
    assert_eq!(listed.len(), 1);
    assert_eq!(listed[0].get("agent").as_usize(), Some(survivor.id() as usize));

    // bit-identical resume semantics: the sharded, interrupted, failed-
    // over lineage must end in EXACTLY the checkpoint an uninterrupted
    // single-process run of the same spec produces
    let (_, state) = checkpoint::load_full(&ckpt).unwrap();
    let state = state.expect("final checkpoint carries training state");
    assert_eq!(state.epochs_done, epochs);

    let mut cfg = elasticzo::config::Config::default();
    for (k, val) in [
        ("method", "full-zo"),
        ("precision", "fp32"),
        ("engine", "native"),
        ("batch", "16"),
        ("train_n", "64"),
        ("test_n", "32"),
        ("seed", "5"),
    ] {
        cfg.set(k, val).unwrap();
    }
    cfg.set("epochs", &epochs.to_string()).unwrap();
    cfg.set("save", &ckpt_straight).unwrap();
    cfg.validate().unwrap();
    let l = launch::run(&cfg, StopFlag::default(), ProgressSink::default()).unwrap();
    assert!(!l.result.stopped);

    let (tensors_sharded, _) = checkpoint::load_full(&ckpt).unwrap();
    let (tensors_straight, straight) = checkpoint::load_full(&ckpt_straight).unwrap();
    let straight = straight.unwrap();
    assert_eq!(
        tensors_sharded, tensors_straight,
        "failed-over params must be bit-identical to the uninterrupted run"
    );
    assert_eq!(state.step, straight.step, "ZO stream positions must match");
    assert_eq!(state.best_test_acc, straight.best_test_acc);
    assert_eq!(state.last_test_loss, straight.last_test_loss);
    assert_eq!(state.last_test_acc, straight.last_test_acc);

    survivor.stop();
    shutdown(&addr, h);
    std::fs::remove_file(&ckpt).ok();
    std::fs::remove_file(&ckpt_straight).ok();
}
