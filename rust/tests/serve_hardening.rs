//! Regression coverage for the serve control-plane hardening:
//!
//! * a submission racing the shutdown gets a truthful 503, never the
//!   misleading "queue full, retry" 429;
//! * journal replay of a durable backlog larger than `queue_cap`
//!   requeues every job (capacity must never destroy admitted jobs);
//! * a slow/stalled client cannot block `/healthz` (or anything else)
//!   behind its socket timeout.

use elasticzo::config::Config;
use elasticzo::serve::{request, Journal, JobSpec, ServeOptions, Server};
use elasticzo::util::json::{self, Value};
use std::io::Write;
use std::time::{Duration, Instant};

fn tiny_spec() -> JobSpec {
    let mut cfg = Config::default();
    cfg.set("engine", "native").unwrap();
    cfg.set("method", "cls1").unwrap();
    cfg.set("epochs", "1").unwrap();
    cfg.set("batch", "16").unwrap();
    cfg.set("train_n", "48").unwrap();
    cfg.set("test_n", "32").unwrap();
    cfg.validate().unwrap();
    JobSpec::new(cfg)
}

/// A job that cannot finish within the test (cancelled/stopped at the
/// end) — keeps queue-depth assertions race-free.
fn long_spec() -> JobSpec {
    let mut spec = tiny_spec();
    spec.config.set("method", "full-zo").unwrap();
    spec.config.set("epochs", "10000").unwrap();
    spec.config.validate().unwrap();
    spec
}

#[test]
fn submit_after_shutdown_start_is_503_not_429() {
    let server = Server::bind(&ServeOptions {
        port: 0,
        workers: 1,
        queue_cap: 4,
        ..Default::default()
    })
    .unwrap();

    // before shutdown a submission is accepted normally
    let (status, v) = server.inject("POST", "/jobs", Some(&tiny_spec().to_json()));
    assert_eq!(status, 200, "{}", json::to_string(&v));

    let (status, _) = server.inject("POST", "/shutdown", None);
    assert_eq!(status, 200);

    // after shutdown began, the queue is closed: the rejection must say
    // so (503, terminal for this instance) — NOT "queue full" (429,
    // which invites a pointless retry against a dying server)
    let (status, v) = server.inject("POST", "/jobs", Some(&tiny_spec().to_json()));
    assert_eq!(status, 503, "expected unavailable, got {status}: {}", json::to_string(&v));
    let msg = v.get("error").as_str().unwrap();
    assert!(msg.contains("shutting down"), "error must name the shutdown: {msg}");
    assert_eq!(v.get("capacity"), &Value::Null, "503 is not a capacity problem");

    // the rejected job leaves no trace in the table
    let (_, listing) = server.inject("GET", "/jobs", None);
    assert_eq!(listing.get("jobs").as_arr().unwrap().len(), 1);
}

#[test]
fn replay_backlog_larger_than_queue_cap_requeues_everything() {
    let dir = std::env::temp_dir().join(format!("ezo_hardening_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("backlog.jsonl").display().to_string();
    std::fs::remove_file(&journal).ok();

    // a previous life admitted 6 jobs that never ran; this life has a
    // much smaller queue (long specs so the pool cannot drain the
    // backlog mid-assertion)
    const BACKLOG: usize = 6;
    {
        let j = Journal::open(&journal).unwrap();
        for id in 1..=BACKLOG as u64 {
            j.append(&Value::obj(vec![
                ("event", Value::str("submit")),
                ("id", Value::num(id as f64)),
                ("ts", Value::num(123.0)),
                ("spec", long_spec().to_json()),
            ]));
        }
    }

    let server = Server::bind(&ServeOptions {
        port: 0,
        workers: 1,
        queue_cap: 2,
        journal: Some(journal.clone()),
        ..Default::default()
    })
    .unwrap();

    // every replayed job must be admitted — previously jobs beyond
    // queue_cap were permanently fail()ed at startup
    let (_, listing) = server.inject("GET", "/jobs", None);
    let jobs = listing.get("jobs").as_arr().unwrap();
    assert_eq!(jobs.len(), BACKLOG);
    for job in jobs {
        let state = job.get("state").as_str().unwrap();
        assert_ne!(
            state, "failed",
            "replay must never destroy a durable job (id {:?})",
            job.get("id").as_usize()
        );
    }

    // fresh submissions still see capacity backpressure (the bypass is
    // for admitted jobs only): with the queue already over capacity a
    // new submit must be rejected with 429
    let (status, v) = server.inject("POST", "/jobs", Some(&long_spec().to_json()));
    assert_eq!(status, 429, "fresh submissions still see backpressure: {}", json::to_string(&v));

    let (status, _) = server.inject("POST", "/shutdown", None);
    assert_eq!(status, 200);
    std::fs::remove_file(&journal).ok();
}

#[test]
fn healthz_answers_while_another_connection_stalls() {
    let server = Server::bind(&ServeOptions {
        port: 0,
        workers: 1,
        queue_cap: 4,
        ..Default::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || server.run().unwrap());

    // a client connects and sends half a request, then goes quiet —
    // its handler thread sits in read() for up to the 10 s socket
    // timeout
    let mut stalled = std::net::TcpStream::connect(&addr).unwrap();
    stalled.write_all(b"POST /jobs HTTP/1.1\r\nContent-Le").unwrap();
    std::thread::sleep(Duration::from_millis(50));

    // the control plane must keep answering regardless (the old
    // single-threaded acceptor served connections inline and would
    // block here for the full timeout)
    let t0 = Instant::now();
    let (status, v) = request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(v.get("ok").as_bool(), Some(true));
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "healthz blocked behind a stalled connection for {:?}",
        t0.elapsed()
    );

    // submissions flow too
    let (status, _) = request(&addr, "POST", "/jobs", Some(&tiny_spec().to_json())).unwrap();
    assert_eq!(status, 200);

    drop(stalled);
    let (status, _) = request(&addr, "POST", "/shutdown", None).unwrap();
    assert_eq!(status, 200);
    h.join().unwrap();
}
