//! Unified-session-API integration tests: the fp32 and int8 stacks must
//! behave identically wherever the shared `coordinator::session::run`
//! loop is in charge — epoch count, eval cadence with carry-forward,
//! and cooperative stop semantics — because it is literally the same
//! loop (PR acceptance: exactly one epoch loop in the coordinator).

use elasticzo::coordinator::control::{ProgressSink, StopFlag};
use elasticzo::coordinator::native_engine::NativeEngine;
use elasticzo::coordinator::{
    int8_trainer, trainer, Method, Model, ParamSet, PrecisionSpec, TrainResult, TrainSpec,
    ZoGradMode,
};
use elasticzo::data::{self, DatasetKind};
use elasticzo::int8::lenet8;

fn fp32_spec(method: Method, epochs: usize, eval_every: usize) -> TrainSpec {
    TrainSpec { method, epochs, batch: 16, eval_every, seed: 5, ..Default::default() }
}

fn int8_spec(method: Method, epochs: usize, eval_every: usize) -> TrainSpec {
    TrainSpec {
        precision: PrecisionSpec::int8(ZoGradMode::FloatCE),
        ..fp32_spec(method, epochs, eval_every)
    }
}

fn run_fp32(spec: &TrainSpec) -> TrainResult {
    let (train_d, test_d) = data::generate(DatasetKind::SynthMnist, 96, 48, 21, 0);
    let mut eng = NativeEngine::new(Model::LeNet);
    let mut params = ParamSet::init(Model::LeNet, 22);
    trainer::train(&mut eng, &mut params, &train_d, &test_d, spec).unwrap()
}

fn run_int8(spec: &TrainSpec) -> TrainResult {
    let (train_d, test_d) = data::generate(DatasetKind::SynthMnist, 96, 48, 21, 0);
    let mut ws = lenet8::init_params(23, 32);
    int8_trainer::train_int8(&mut ws, &train_d, &test_d, spec).unwrap()
}

/// Eval-cadence carry-forward pattern of a history: `true` where the
/// epoch reused the previous epoch's eval instead of re-evaluating.
fn carry_pattern(r: &TrainResult) -> Vec<bool> {
    r.history
        .epochs
        .windows(2)
        .map(|w| w[1].test_loss == w[0].test_loss && w[1].test_acc == w[0].test_acc)
        .collect()
}

#[test]
fn fp32_and_int8_share_epoch_and_eval_semantics() {
    // same spec shape -> same loop behaviour on both stacks
    let rf = run_fp32(&fp32_spec(Method::CLS1, 5, 2));
    let ri = run_int8(&int8_spec(Method::CLS1, 5, 2));
    for (label, r) in [("fp32", &rf), ("int8", &ri)] {
        assert_eq!(r.history.epochs.len(), 5, "{label}: one stats row per epoch");
        assert!(!r.stopped, "{label}");
        // eval at epochs 0, 2, 4 — epochs 1 and 3 carry forward
        let carries = carry_pattern(r);
        assert!(carries[0] && carries[2], "{label}: off-cadence epochs must carry, {carries:?}");
        // both stacks report live train accuracy through the shared loop
        let last = r.history.epochs.last().unwrap();
        assert!(last.train_acc > 0.0 && last.train_acc <= 1.0, "{label}");
    }
    // fresh evals actually happen on-cadence (fp32 float means make a
    // coincidental exact repeat effectively impossible)
    let carries = carry_pattern(&rf);
    assert!(!carries[1] && !carries[3], "fp32: on-cadence epochs must re-evaluate, {carries:?}");
    // the labels identify the grid cell
    assert_eq!(rf.history.label, "ZO-Feat-Cls1");
    assert_eq!(ri.history.label, "ZO-Feat-Cls1 INT8");
}

#[test]
fn full_bp_drives_the_same_loop_with_live_train_acc() {
    // acceptance: Full BP on BOTH precisions reports nonzero train_acc
    let rf = run_fp32(&fp32_spec(Method::FullBp, 2, 1));
    let ri = run_int8(&int8_spec(Method::FullBp, 2, 1));
    for (label, r) in [("fp32", &rf), ("int8", &ri)] {
        let last = r.history.epochs.last().unwrap();
        assert!(last.train_acc > 0.0, "{label}: Full BP train_acc must be live");
    }
}

#[test]
fn stop_semantics_identical_across_precisions() {
    // firing the stop flag from the epoch-0 progress callback must end
    // both stacks after exactly one recorded epoch
    let arm = |spec: &mut TrainSpec| {
        let stop = StopFlag::new();
        let stop2 = stop.clone();
        spec.progress = ProgressSink::new(move |e| {
            if e.epoch == 0 {
                stop2.request_stop();
            }
        });
        spec.stop = stop;
    };
    let mut sf = fp32_spec(Method::CLS2, 50, 1);
    arm(&mut sf);
    let rf = run_fp32(&sf);
    let mut si = int8_spec(Method::CLS2, 50, 1);
    arm(&mut si);
    let ri = run_int8(&si);
    for (label, r) in [("fp32", &rf), ("int8", &ri)] {
        assert!(r.stopped, "{label}");
        assert_eq!(r.history.epochs.len(), 1, "{label}: must stop right after epoch 0");
    }
}
