//! Engine parity: the XLA (AOT artifact) engine and the native rust
//! engine must agree on every Engine method — loss, logits, partition
//! activations, tail gradients and full-BP steps — for both models.
//! This is the cross-check that pins the three-layer stack to the
//! reference implementation. Skipped when artifacts/ is absent.
//! Compiled only with the `xla` cargo feature (needs the PJRT runtime).

#![cfg(feature = "xla")]

use elasticzo::coordinator::native_engine::NativeEngine;
use elasticzo::coordinator::xla_engine::XlaEngine;
use elasticzo::coordinator::{Engine, Model, ParamSet};
use elasticzo::data;

fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

fn lenet_batch(bsz: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let d = data::synth_mnist::generate(bsz, seed);
    let mut y = vec![0.0f32; bsz * 10];
    for (i, &l) in d.labels.iter().enumerate() {
        y[i * 10 + l as usize] = 1.0;
    }
    (d.x, y)
}

fn xla(model: Model, bsz: usize) -> Option<XlaEngine> {
    match XlaEngine::open_default(model, bsz) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping parity test: {e:#}");
            None
        }
    }
}

#[test]
fn lenet_forward_parity() {
    let Some(mut xe) = xla(Model::LeNet, 32) else { return };
    let mut ne = NativeEngine::new(Model::LeNet);
    let params = ParamSet::init(Model::LeNet, 77);
    let (x, y) = lenet_batch(32, 78);
    let fx = xe.forward(&params, &x, &y, 32).unwrap();
    let fnv = ne.forward(&params, &x, &y, 32).unwrap();
    assert!(close(fx.loss, fnv.loss, 1e-3), "{} vs {}", fx.loss, fnv.loss);
    for (a, b) in fx.logits.iter().zip(&fnv.logits) {
        assert!(close(*a, *b, 1e-3));
    }
    for (a, b) in fx.act_c1.iter().zip(&fnv.act_c1) {
        assert!(close(*a, *b, 1e-3));
    }
    for (a, b) in fx.act_c2.iter().zip(&fnv.act_c2) {
        assert!(close(*a, *b, 1e-3));
    }
}

#[test]
fn lenet_tail_grads_parity() {
    let Some(mut xe) = xla(Model::LeNet, 32) else { return };
    let mut ne = NativeEngine::new(Model::LeNet);
    let params = ParamSet::init(Model::LeNet, 80);
    let (x, y) = lenet_batch(32, 81);
    let fwd = ne.forward(&params, &x, &y, 32).unwrap();
    for k in [1usize, 2] {
        let gx = xe.tail_grads(&params, &fwd, &y, k, 32).unwrap();
        let gn = ne.tail_grads(&params, &fwd, &y, k, 32).unwrap();
        assert_eq!(gx.len(), gn.len());
        for ((ix, vx), (inn, vn)) in gx.iter().zip(&gn) {
            assert_eq!(ix, inn, "tail grad index ordering");
            for (a, b) in vx.iter().zip(vn) {
                assert!((a - b).abs() < 1e-4 + 1e-3 * b.abs(), "k={k} idx={ix}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn lenet_full_step_parity() {
    let Some(mut xe) = xla(Model::LeNet, 32) else { return };
    let mut ne = NativeEngine::new(Model::LeNet);
    let mut px = ParamSet::init(Model::LeNet, 83);
    let mut pn = px.clone();
    let (x, y) = lenet_batch(32, 84);
    let sx = xe.full_step(&mut px, &x, &y, 32, 0.05).unwrap();
    let sn = ne.full_step(&mut pn, &x, &y, 32, 0.05).unwrap();
    assert!(close(sx.loss, sn.loss, 1e-3));
    // logits parity when the artifact set exposes them (newer compiles)
    if let (Some(lx), Some(ln)) = (&sx.logits, &sn.logits) {
        for (a, b) in lx.iter().zip(ln) {
            assert!(close(*a, *b, 1e-3));
        }
    }
    // updated parameters must match across engines
    for (tx, tn) in px.data.iter().zip(&pn.data) {
        for (a, b) in tx.iter().zip(tn) {
            assert!((a - b).abs() < 1e-4 + 1e-3 * b.abs(), "{a} vs {b}");
        }
    }
}

#[test]
fn pointnet_forward_parity() {
    let model = Model::PointNet { npoints: 128, ncls: 40 };
    let Some(mut xe) = xla(model, 16) else { return };
    let mut ne = NativeEngine::new(model);
    let params = ParamSet::init(model, 85);
    let d = data::synth_modelnet::generate(16, 128, 86);
    let mut y = vec![0.0f32; 16 * 40];
    for (i, &l) in d.labels.iter().enumerate() {
        y[i * 40 + l as usize] = 1.0;
    }
    let fx = xe.forward(&params, &d.x, &y, 16).unwrap();
    let fnv = ne.forward(&params, &d.x, &y, 16).unwrap();
    assert!(close(fx.loss, fnv.loss, 1e-3), "{} vs {}", fx.loss, fnv.loss);
    for (a, b) in fx.logits.iter().zip(&fnv.logits) {
        assert!(close(*a, *b, 2e-3));
    }
}

#[test]
fn pallas_and_fast_forward_agree() {
    // the Pallas-interpret artifact and the fast reference-ops artifact
    // lower the SAME math — loss must agree to float tolerance.
    std::env::set_var("REPRO_PALLAS_FWD", "1");
    let pallas = xla(Model::LeNet, 8);
    std::env::remove_var("REPRO_PALLAS_FWD");
    let Some(mut pe) = pallas else { return };
    let Some(mut fe) = xla(Model::LeNet, 8) else { return };
    let params = ParamSet::init(Model::LeNet, 90);
    let (x, y) = lenet_batch(8, 91);
    let fp = pe.forward(&params, &x, &y, 8).unwrap();
    let ff = fe.forward(&params, &x, &y, 8).unwrap();
    assert!(close(fp.loss, ff.loss, 1e-3), "{} vs {}", fp.loss, ff.loss);
    for (a, b) in fp.logits.iter().zip(&ff.logits) {
        assert!(close(*a, *b, 1e-3));
    }
}

#[test]
fn batch_size_mismatch_is_error() {
    let Some(mut xe) = xla(Model::LeNet, 32) else { return };
    let params = ParamSet::init(Model::LeNet, 92);
    let (x, y) = lenet_batch(8, 93);
    assert!(xe.forward(&params, &x, &y, 8).is_err());
}
