//! Engine parity and loss-diff edge cases.
//!
//! The `xla_parity` module (compiled only with the `xla` cargo
//! feature; needs the PJRT runtime, skipped when artifacts/ is absent)
//! pins the XLA (AOT artifact) engine to the native rust engine on
//! every Engine method — loss, logits, partition activations, tail
//! gradients and full-BP steps — for both models.
//!
//! The ungated tests below pin the ZO loss-difference math at its
//! edges: exact-zero δ, g_clip saturation on both signs, ε down at
//! f32 denormal scale, and the integer CE decision at operand
//! magnitudes far past the i32 accumulation boundary (`int8/intce.rs`
//! accumulates in i64 — these tests are what make that a contract).

use elasticzo::coordinator::zo;
use elasticzo::int8::intce;
use elasticzo::rng::Rng64;

#[test]
fn zero_delta_projects_to_exact_zero() {
    // l₊ == l₋ must yield the positive-zero gradient bit pattern, not
    // merely something small — the int8 g==0 fast path and the dp
    // commit log both branch on it
    for l in [0.0f32, 1.0, 2.3e4, f32::MIN_POSITIVE] {
        for eps in [1e-2f32, 1e-6] {
            let g = zo::projected_gradient(l, l, eps, 5.0);
            assert_eq!(g.to_bits(), 0.0f32.to_bits(), "l={l} eps={eps}");
        }
    }
    assert_eq!(zo::projected_gradient_from_delta(0.0, 1e-2, 5.0).to_bits(), 0.0f32.to_bits());
}

#[test]
fn g_clip_saturates_exactly_on_both_signs() {
    let clip = 5.0f32;
    // |δ|/2ε far above the clip: the result must be the clip value
    // itself, bit for bit, on either sign
    let g_pos = zo::projected_gradient(1e3, 0.0, 1e-3, clip);
    let g_neg = zo::projected_gradient(0.0, 1e3, 1e-3, clip);
    assert_eq!(g_pos.to_bits(), clip.to_bits());
    assert_eq!(g_neg.to_bits(), (-clip).to_bits());
    // and just inside the clip nothing saturates
    let g_in = zo::projected_gradient(1e-3, 0.0, 1e-3, clip);
    assert!(g_in.abs() < clip);
}

#[test]
fn denormal_eps_never_produces_nan_and_stays_clipped() {
    let clip = 5.0f32;
    let denormal = f32::MIN_POSITIVE / 4.0; // ~2.9e-39, subnormal
    assert!(denormal > 0.0 && !denormal.is_normal());
    for delta in [denormal, -denormal, 1.0f32, -1.0, f32::MIN_POSITIVE] {
        let g = zo::projected_gradient_from_delta(delta, denormal, clip);
        assert!(g.is_finite(), "delta={delta}: g={g}");
        assert!(g.abs() <= clip, "delta={delta}: g={g}");
        assert_eq!(g.signum(), delta.signum(), "delta={delta}");
    }
    // a denormal δ against a normal ε underflows toward zero quietly
    let g = zo::projected_gradient_from_delta(denormal, 1e-2, clip);
    assert!(g.is_finite() && g.abs() < 1e-30);
}

#[test]
fn projected_gradient_and_from_delta_agree_bitwise() {
    // the two spellings feed the same trajectory (local step vs dp
    // commit log) and must never drift apart
    let mut rng = Rng64::new(3);
    for _ in 0..200 {
        let lp = rng.uniform() * 4.0;
        let lm = rng.uniform() * 4.0;
        let eps = 10f32.powi(-((rng.next_u64() % 6) as i32) - 1);
        let g1 = zo::projected_gradient(lp, lm, eps, 5.0);
        let g2 = zo::projected_gradient_from_delta(lp - lm, eps, 5.0);
        assert_eq!(g1.to_bits(), g2.to_bits(), "lp={lp} lm={lm} eps={eps}");
    }
}

#[test]
fn intce_survives_exponents_past_the_i32_boundary() {
    // s_a=30 against s_b=0 makes the rescaled logit difference reach
    // ~510·2^30 and the Q15 product ~2.6e16 — orders of magnitude past
    // i32::MAX. The decision must come out in range (no debug-overflow
    // panic anywhere in the i64 pipeline) and the f64 oracle must stay
    // finite on the same inputs.
    let (bsz, n) = (8usize, 10usize);
    let mut rng = Rng64::new(29);
    for &(s_a, s_b) in &[(30i32, 0i32), (0, 30), (30, 30), (-30, -30), (15, -15)] {
        for _ in 0..20 {
            let alpha: Vec<i8> = (0..bsz * n).map(|_| rng.uniform_i32(-127, 127) as i8).collect();
            let beta: Vec<i8> = (0..bsz * n).map(|_| rng.uniform_i32(-127, 127) as i8).collect();
            let labels: Vec<u8> = (0..bsz).map(|_| (rng.next_u64() % n as u64) as u8).collect();
            let g = intce::loss_diff_sign_int(&alpha, s_a, &beta, s_b, &labels, bsz, n);
            assert!((-1..=1).contains(&g));
            let exact = intce::loss_diff_f32(&alpha, s_a, &beta, s_b, &labels, bsz, n);
            assert!(exact.is_finite(), "oracle blew up at s_a={s_a} s_b={s_b}");
        }
        // an unambiguous pair at the same extremes: alpha confident on
        // the label, beta uniform — L(α) < L(β), so the sign must be −1
        // whenever the rescaled hats still resolve (they do for every
        // pair here with a positive max exponent)
        if s_a.max(s_b) >= 0 {
            let mut alpha = vec![-60i8; bsz * n];
            let labels: Vec<u8> = vec![3; bsz];
            for b in 0..bsz {
                alpha[b * n + 3] = 120;
            }
            let beta = vec![0i8; bsz * n];
            let g = intce::loss_diff_sign_int(&alpha, s_a, &beta, s_b, &labels, bsz, n);
            assert_eq!(g, -1, "s_a={s_a} s_b={s_b}");
        }
    }
}

#[test]
fn intce_antisymmetric_at_extreme_exponents() {
    let (bsz, n) = (4usize, 10usize);
    let mut rng = Rng64::new(31);
    for &(s_a, s_b) in &[(30i32, 0i32), (15, -15), (-30, -30)] {
        for _ in 0..20 {
            let alpha: Vec<i8> = (0..bsz * n).map(|_| rng.uniform_i32(-127, 127) as i8).collect();
            let beta: Vec<i8> = (0..bsz * n).map(|_| rng.uniform_i32(-127, 127) as i8).collect();
            let labels: Vec<u8> = (0..bsz).map(|_| (rng.next_u64() % n as u64) as u8).collect();
            let g1 = intce::loss_diff_sign_int(&alpha, s_a, &beta, s_b, &labels, bsz, n);
            let g2 = intce::loss_diff_sign_int(&beta, s_b, &alpha, s_a, &labels, bsz, n);
            assert_eq!(g1, -g2, "s_a={s_a} s_b={s_b}");
        }
    }
}

#[test]
fn intce_saturated_identical_rows_are_a_tie() {
    // all-saturated logits on both sides, equal exponents: δ is exactly
    // zero and the integer path must say so even at the i8 rails
    let (bsz, n) = (4usize, 10usize);
    let row: Vec<i8> = (0..bsz * n).map(|i| if i % n == 0 { 127 } else { -128 }).collect();
    let labels = vec![0u8; bsz];
    assert_eq!(intce::loss_diff_sign_int(&row, 7, &row, 7, &labels, bsz, n), 0);
}

#[cfg(feature = "xla")]
mod xla_parity {
    use elasticzo::coordinator::native_engine::NativeEngine;
    use elasticzo::coordinator::xla_engine::XlaEngine;
    use elasticzo::coordinator::{Engine, Model, ParamSet};
    use elasticzo::data;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    fn lenet_batch(bsz: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let d = data::synth_mnist::generate(bsz, seed);
        let mut y = vec![0.0f32; bsz * 10];
        for (i, &l) in d.labels.iter().enumerate() {
            y[i * 10 + l as usize] = 1.0;
        }
        (d.x, y)
    }

    fn xla(model: Model, bsz: usize) -> Option<XlaEngine> {
        match XlaEngine::open_default(model, bsz) {
            Ok(e) => Some(e),
            Err(e) => {
                eprintln!("skipping parity test: {e:#}");
                None
            }
        }
    }

    #[test]
    fn lenet_forward_parity() {
        let Some(mut xe) = xla(Model::LeNet, 32) else { return };
        let mut ne = NativeEngine::new(Model::LeNet);
        let params = ParamSet::init(Model::LeNet, 77);
        let (x, y) = lenet_batch(32, 78);
        let fx = xe.forward(&params, &x, &y, 32).unwrap();
        let fnv = ne.forward(&params, &x, &y, 32).unwrap();
        assert!(close(fx.loss, fnv.loss, 1e-3), "{} vs {}", fx.loss, fnv.loss);
        for (a, b) in fx.logits.iter().zip(&fnv.logits) {
            assert!(close(*a, *b, 1e-3));
        }
        for (a, b) in fx.act_c1.iter().zip(&fnv.act_c1) {
            assert!(close(*a, *b, 1e-3));
        }
        for (a, b) in fx.act_c2.iter().zip(&fnv.act_c2) {
            assert!(close(*a, *b, 1e-3));
        }
    }

    #[test]
    fn lenet_tail_grads_parity() {
        let Some(mut xe) = xla(Model::LeNet, 32) else { return };
        let mut ne = NativeEngine::new(Model::LeNet);
        let params = ParamSet::init(Model::LeNet, 80);
        let (x, y) = lenet_batch(32, 81);
        let fwd = ne.forward(&params, &x, &y, 32).unwrap();
        for k in [1usize, 2] {
            let gx = xe.tail_grads(&params, &fwd, &y, k, 32).unwrap();
            let gn = ne.tail_grads(&params, &fwd, &y, k, 32).unwrap();
            assert_eq!(gx.len(), gn.len());
            for ((ix, vx), (inn, vn)) in gx.iter().zip(&gn) {
                assert_eq!(ix, inn, "tail grad index ordering");
                for (a, b) in vx.iter().zip(vn) {
                    assert!((a - b).abs() < 1e-4 + 1e-3 * b.abs(), "k={k} idx={ix}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn lenet_full_step_parity() {
        let Some(mut xe) = xla(Model::LeNet, 32) else { return };
        let mut ne = NativeEngine::new(Model::LeNet);
        let mut px = ParamSet::init(Model::LeNet, 83);
        let mut pn = px.clone();
        let (x, y) = lenet_batch(32, 84);
        let sx = xe.full_step(&mut px, &x, &y, 32, 0.05).unwrap();
        let sn = ne.full_step(&mut pn, &x, &y, 32, 0.05).unwrap();
        assert!(close(sx.loss, sn.loss, 1e-3));
        // logits parity when the artifact set exposes them (newer compiles)
        if let (Some(lx), Some(ln)) = (&sx.logits, &sn.logits) {
            for (a, b) in lx.iter().zip(ln) {
                assert!(close(*a, *b, 1e-3));
            }
        }
        // updated parameters must match across engines
        for (tx, tn) in px.data.iter().zip(&pn.data) {
            for (a, b) in tx.iter().zip(tn) {
                assert!((a - b).abs() < 1e-4 + 1e-3 * b.abs(), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn pointnet_forward_parity() {
        let model = Model::PointNet { npoints: 128, ncls: 40 };
        let Some(mut xe) = xla(model, 16) else { return };
        let mut ne = NativeEngine::new(model);
        let params = ParamSet::init(model, 85);
        let d = data::synth_modelnet::generate(16, 128, 86);
        let mut y = vec![0.0f32; 16 * 40];
        for (i, &l) in d.labels.iter().enumerate() {
            y[i * 40 + l as usize] = 1.0;
        }
        let fx = xe.forward(&params, &d.x, &y, 16).unwrap();
        let fnv = ne.forward(&params, &d.x, &y, 16).unwrap();
        assert!(close(fx.loss, fnv.loss, 1e-3), "{} vs {}", fx.loss, fnv.loss);
        for (a, b) in fx.logits.iter().zip(&fnv.logits) {
            assert!(close(*a, *b, 2e-3));
        }
    }

    #[test]
    fn pallas_and_fast_forward_agree() {
        // the Pallas-interpret artifact and the fast reference-ops artifact
        // lower the SAME math — loss must agree to float tolerance.
        std::env::set_var("REPRO_PALLAS_FWD", "1");
        let pallas = xla(Model::LeNet, 8);
        std::env::remove_var("REPRO_PALLAS_FWD");
        let Some(mut pe) = pallas else { return };
        let Some(mut fe) = xla(Model::LeNet, 8) else { return };
        let params = ParamSet::init(Model::LeNet, 90);
        let (x, y) = lenet_batch(8, 91);
        let fp = pe.forward(&params, &x, &y, 8).unwrap();
        let ff = fe.forward(&params, &x, &y, 8).unwrap();
        assert!(close(fp.loss, ff.loss, 1e-3), "{} vs {}", fp.loss, ff.loss);
        for (a, b) in fp.logits.iter().zip(&ff.logits) {
            assert!(close(*a, *b, 1e-3));
        }
    }

    #[test]
    fn batch_size_mismatch_is_error() {
        let Some(mut xe) = xla(Model::LeNet, 32) else { return };
        let params = ParamSet::init(Model::LeNet, 92);
        let (x, y) = lenet_batch(8, 93);
        assert!(xe.forward(&params, &x, &y, 8).is_err());
    }
}
