//! Metrics e2e: scrape `GET /metrics` off a live cluster coordinator
//! (remote-agent job in flight) and hold the exposition to the
//! Prometheus text-format contract — `# TYPE` coverage for every
//! sample, counter monotonicity across scrapes, histogram bucket
//! arithmetic — plus the cluster-seam observability this PR wires up:
//! per-phase histograms fed by a REMOTE job's epoch reports, the
//! per-job `phase_seconds` breakdown, and the sliding-window /
//! event-bus fields in `GET /stats`.

use elasticzo::serve::{
    request, Agent, AgentHandle, AgentOptions, ClusterOptions, ServeOptions, Server,
};
use elasticzo::util::json::Value;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

const LONG: Duration = Duration::from_secs(300);

fn start_coordinator() -> (String, JoinHandle<()>) {
    let server = Server::bind(&ServeOptions {
        port: 0,
        workers: 0, // pure coordinator: the job MUST run on the agent
        queue_cap: 8,
        journal: None,
        cluster: Some(ClusterOptions { lease_ms: 10_000 }),
        ..Default::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || server.run().unwrap());
    (addr, h)
}

fn spawn_agent(addr: &str) -> AgentHandle {
    Agent::spawn(AgentOptions {
        coordinator: addr.to_string(),
        capacity: 1,
        name: "metrics-edge".to_string(),
        poll_ms: 50,
        max_poll_failures: 40,
        mem_budget: None,
    })
    .unwrap()
}

fn submit(addr: &str, spec: &str) -> u64 {
    let body = elasticzo::util::json::parse(spec).unwrap();
    let (status, v) = request(addr, "POST", "/jobs", Some(&body)).unwrap();
    assert_eq!(status, 200, "submit failed: {}", elasticzo::util::json::to_string(&v));
    v.get("id").as_f64().unwrap() as u64
}

fn poll_until(addr: &str, id: u64, pred: impl Fn(&Value) -> bool, what: &str) -> Value {
    let t0 = Instant::now();
    loop {
        let (status, v) = request(addr, "GET", &format!("/jobs/{id}"), None).unwrap();
        assert_eq!(status, 200, "job {id} must exist");
        if pred(&v) {
            return v;
        }
        assert!(t0.elapsed() < LONG, "timed out waiting for {what} on job {id}");
        std::thread::sleep(Duration::from_millis(30));
    }
}

/// Raw HTTP scrape: the shared JSON client refuses non-JSON bodies, and
/// the exposition is text/plain by design.
fn scrape(addr: &str) -> (String, String) {
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    write!(s, "GET /metrics HTTP/1.1\r\nHost: repro\r\nConnection: close\r\n\r\n").unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8(buf).expect("exposition must be UTF-8");
    let (head, body) = text.split_once("\r\n\r\n").expect("header/body split");
    (head.to_string(), body.to_string())
}

fn parse_value(s: &str) -> f64 {
    match s {
        "+Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        other => other.parse().unwrap_or_else(|_| panic!("bad sample value {other:?}")),
    }
}

/// `(family -> declared type, series -> value)` from one exposition.
fn parse_exposition(body: &str) -> (BTreeMap<String, String>, BTreeMap<String, f64>) {
    let mut types = BTreeMap::new();
    let mut series = BTreeMap::new();
    for line in body.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("TYPE line has a name");
            let kind = it.next().expect("TYPE line has a kind");
            types.insert(name.to_string(), kind.to_string());
        } else if !line.starts_with('#') && !line.is_empty() {
            let (name, value) = line.rsplit_once(' ').expect("sample is `name value`");
            series.insert(name.to_string(), parse_value(value));
        }
    }
    (types, series)
}

/// Family a sample belongs to (histogram samples carry suffixes).
fn family_of(series: &str) -> String {
    let name = series.split('{').next().unwrap();
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = name.strip_suffix(suffix) {
            return base.to_string();
        }
    }
    name.to_string()
}

/// Strip the `le` label off a `_bucket` series so it can be matched
/// with its `_count` line (`le` is always rendered last).
fn without_le(series: &str) -> String {
    let open = series.find('{').unwrap();
    let labels = &series[open + 1..series.len() - 1];
    let kept: Vec<&str> =
        labels.split(',').filter(|kv| !kv.starts_with("le=")).collect();
    if kept.is_empty() {
        series[..open].to_string()
    } else {
        format!("{}{{{}}}", &series[..open], kept.join(","))
    }
}

#[test]
fn boundary_gauge_and_change_counter_cover_an_elastic_job() {
    let (addr, h) = start_coordinator();
    // a 1-byte budget: negotiation pins the job to the elastic FLOOR
    // (k=0, already the spec's method, so no pin event) and leaves the
    // plateau controller all the headroom to deepen mid-run
    let agent = Agent::spawn(AgentOptions {
        coordinator: addr.to_string(),
        capacity: 1,
        name: "tight-budget".to_string(),
        poll_ms: 50,
        max_poll_failures: 40,
        mem_budget: Some(1),
    })
    .unwrap();

    // huge eps + patience 1 ⇒ every eval is a plateau: the controller
    // deepens at epoch 1 and again at epoch 2 (elastic:0-2)
    let id = submit(
        &addr,
        r#"{"method": "full-zo", "boundary": "elastic:0-2", "elastic_patience": 1,
            "elastic_eps": 100, "precision": "fp32", "engine": "native",
            "epochs": 3, "batch": 16, "train_n": 64, "test_n": 32, "seed": 5}"#,
    );
    poll_until(&addr, id, |v| v.get("state").as_str() == Some("done"), "elastic job done");

    let (_, body) = scrape(&addr);
    let (types, series) = parse_exposition(&body);
    assert!(types.contains_key("repro_boundary"), "missing # TYPE repro_boundary\n{body}");
    assert!(
        types.contains_key("repro_boundary_changes_total"),
        "missing # TYPE repro_boundary_changes_total\n{body}"
    );
    let gauge = format!("repro_boundary{{job=\"{id}\"}}");
    assert_eq!(
        series.get(&gauge),
        Some(&2.0),
        "the job must end at the elastic ceiling k=2: {series:?}"
    );
    assert!(
        series.get("repro_boundary_changes_total").is_some_and(|&v| v >= 2.0),
        "two mid-run boundary moves must be counted"
    );

    // the registry's per-epoch audit trail carries the same schedule
    let (status, v) = request(&addr, "GET", &format!("/jobs/{id}"), None).unwrap();
    assert_eq!(status, 200);
    let ks: Vec<Option<usize>> = v
        .get("history")
        .as_arr()
        .expect("job detail lists its epoch history")
        .iter()
        .map(|e| e.get("bp_tail").as_usize())
        .collect();
    assert_eq!(ks, vec![Some(0), Some(1), Some(2)], "per-epoch bp_tail audit trail");

    // the agent listing surfaces the registered budget
    let (status, v) = request(&addr, "GET", "/cluster/agents", None).unwrap();
    assert_eq!(status, 200);
    let agents = v.get("agents").as_arr().expect("agents listing").to_vec();
    assert!(
        agents.iter().any(|a| a.get("mem_budget").as_usize() == Some(1)),
        "registered mem_budget must be listed: {v:?}"
    );

    agent.stop();
    let (status, _) = request(&addr, "POST", "/shutdown", None).unwrap();
    assert_eq!(status, 200);
    h.join().unwrap();
}

#[test]
fn metrics_exposition_is_conformant_and_covers_a_remote_job() {
    let (addr, h) = start_coordinator();
    let agent = spawn_agent(&addr);

    let id = submit(
        &addr,
        r#"{"method": "cls1", "precision": "fp32", "engine": "native",
            "epochs": 2, "batch": 16, "train_n": 128, "test_n": 32, "seed": 11}"#,
    );
    // first scrape while the job is (very likely) still live — every
    // counter here must only ever grow by the second scrape
    let (head1, body1) = scrape(&addr);
    assert!(head1.starts_with("HTTP/1.1 200"), "scrape status: {head1}");
    assert!(
        head1.contains("text/plain; version=0.0.4"),
        "exposition content type: {head1}"
    );
    let (_, series1) = parse_exposition(&body1);

    let done = poll_until(&addr, id, |v| v.get("state").as_str() == Some("done"), "job done");

    // ---- satellite: the REMOTE job's Fig.-7 breakdown reached the
    // coordinator through the epoch wire ----
    let phases = done.get("phase_seconds");
    assert!(phases.as_obj().is_some(), "remote job detail carries phase_seconds: {done:?}");
    assert!(
        phases.get("Forward").as_f64().unwrap_or(0.0) > 0.0,
        "Forward phase time from the remote agent"
    );
    assert_eq!(done.get("agent").as_usize(), Some(agent.id() as usize), "ran remotely");

    let (_, body2) = scrape(&addr);
    let (types2, series2) = parse_exposition(&body2);

    // ---- presence: everything this PR instruments is exposed ----
    for name in [
        "repro_http_requests_total",
        "repro_http_request_duration_seconds",
        "repro_epochs_total",
        "repro_epoch_seconds",
        "repro_phase_epoch_seconds",
        "repro_job_train_loss",
        "repro_job_train_acc",
        "repro_job_test_acc",
        "repro_queue_depth",
        "repro_jobs",
        "repro_agents",
        "repro_sse_streams_active",
        "repro_sse_lagged_total",
        "repro_events_seq",
        "repro_event_subscribers",
        "repro_mem_live_bytes",
        "repro_mem_peak_bytes",
        "repro_allocs_total",
    ] {
        assert!(types2.contains_key(name), "missing # TYPE for {name}\n{body2}");
    }
    // the remote job's per-phase histogram has real observations
    assert!(
        series2
            .get("repro_phase_epoch_seconds_count{phase=\"Forward\"}")
            .is_some_and(|&v| v >= 2.0),
        "two epochs of Forward observations from the remote agent"
    );
    assert!(
        series2.get("repro_epochs_total").is_some_and(|&v| v >= 2.0),
        "both epochs counted"
    );

    // ---- conformance: every sample's family declares a TYPE ----
    for name in series2.keys() {
        let fam = family_of(name);
        assert!(types2.contains_key(&fam), "sample {name} has no # TYPE {fam}");
    }

    // ---- conformance: counters are monotone across the two scrapes ----
    for (name, v1) in &series1 {
        let fam = family_of(name);
        if types2.get(&fam).map(String::as_str) == Some("counter") {
            if let Some(v2) = series2.get(name) {
                assert!(v2 >= v1, "counter {name} went backwards: {v1} -> {v2}");
            }
        }
    }

    // ---- conformance: histogram bucket arithmetic ----
    // group buckets per series (label set minus `le`), then check the
    // cumulative counts never decrease in NUMERIC le order (the map
    // iterates lexicographically, where "10" < "2.5" and "+Inf" sorts
    // first — that order proves nothing)
    let mut buckets: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
    for (name, &v) in &series2 {
        if !name.contains("_bucket{") {
            continue;
        }
        let le = name
            .split("le=\"")
            .nth(1)
            .and_then(|s| s.split('"').next())
            .expect("bucket sample has an le label");
        buckets.entry(without_le(name)).or_default().push((parse_value(le), v));
    }
    assert!(!buckets.is_empty(), "at least one histogram series rendered");
    for (key, les) in &mut buckets {
        les.sort_by(|a, b| a.0.total_cmp(&b.0));
        assert!(
            les.windows(2).all(|w| w[0].1 <= w[1].1),
            "cumulative bucket counts decreased in {key}: {les:?}"
        );
        let (last_le, inf_cum) = *les.last().unwrap();
        assert_eq!(last_le, f64::INFINITY, "{key} is missing its +Inf bucket");
        let count_series = key.replacen("_bucket", "_count", 1);
        assert_eq!(
            series2.get(&count_series),
            Some(&inf_cum),
            "+Inf bucket must equal _count for {key}"
        );
    }

    // ---- satellite: /stats sliding-window rate + event-bus fields ----
    let (status, s) = request(&addr, "GET", "/stats", None).unwrap();
    assert_eq!(status, 200);
    assert!(s.get("epochs_per_sec").as_f64().unwrap() > 0.0, "fresh epochs in the window");
    let w = s.get("epochs_per_sec_window_seconds").as_f64().unwrap();
    assert!(w > 0.0 && w <= 60.0, "window clamps to min(60s, uptime): {w}");
    assert!(s.get("events_seq").as_usize().unwrap() >= 3, "2 epochs + state changes");
    assert_eq!(s.get("events_subscribers").as_usize(), Some(0));
    assert!(s.get("events_lagged_total").as_usize().is_some());

    agent.stop();
    let (status, _) = request(&addr, "POST", "/shutdown", None).unwrap();
    assert_eq!(status, 200);
    h.join().unwrap();
}
