//! Runtime smoke tests: load real artifacts, execute, and cross-check
//! against the native rust engine. Skipped when artifacts/ is absent
//! (run `make artifacts` first).
//! Compiled only with the `xla` cargo feature (needs the PJRT runtime).

#![cfg(feature = "xla")]

use elasticzo::int8::lenet8;
use elasticzo::nn::lenet;
use elasticzo::rng::Rng64;
use elasticzo::runtime::{ArgValue, Registry};

fn registry() -> Option<Registry> {
    match Registry::open_default() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping runtime smoke test: {e:#}");
            None
        }
    }
}

fn lenet_params(seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng64::new(seed);
    lenet::PARAM_SPECS
        .iter()
        .map(|(_, shape)| {
            let n: usize = shape.iter().product();
            let fan_in = match shape.len() {
                4 => shape[1] * shape[2] * shape[3],
                2 => shape[0],
                _ => n,
            };
            let mut v = vec![0.0f32; n];
            rng.fill_kaiming_uniform(&mut v, fan_in);
            v
        })
        .collect()
}

fn batch(bsz: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<u8>) {
    let d = elasticzo::data::synth_mnist::generate(bsz, seed);
    let mut y = vec![0.0f32; bsz * 10];
    for (i, &l) in d.labels.iter().enumerate() {
        y[i * 10 + l as usize] = 1.0;
    }
    (d.x, y, d.labels)
}

#[test]
fn lenet_fwd_artifact_matches_native_engine() {
    let Some(mut reg) = registry() else { return };
    let params = lenet_params(11);
    let (x, y, _) = batch(8, 22);

    let exe = reg.get("lenet_fwd_b8").expect("artifact lenet_fwd_b8");
    let mut args: Vec<ArgValue> = params.iter().map(|p| ArgValue::F32(p)).collect();
    args.push(ArgValue::F32(&x));
    args.push(ArgValue::F32(&y));
    let out = exe.run(&args).expect("execute");
    let loss_xla = out[0].scalar_f32().unwrap();
    let logits_xla = out[1].as_f32().unwrap();
    let a1_xla = out[2].as_f32().unwrap();
    let a2_xla = out[3].as_f32().unwrap();

    let (fwd, _) = lenet::forward(&params, &x, &y, 8);
    assert!(
        (loss_xla - fwd.loss).abs() < 1e-3 * (1.0 + fwd.loss.abs()),
        "loss xla {loss_xla} vs native {}",
        fwd.loss
    );
    assert_eq!(logits_xla.len(), fwd.logits.len());
    for (a, b) in logits_xla.iter().zip(&fwd.logits) {
        assert!((a - b).abs() < 2e-3 * (1.0 + b.abs()), "logits {a} vs {b}");
    }
    for (a, b) in a1_xla.iter().zip(&fwd.act_c2) {
        assert!((a - b).abs() < 2e-3 * (1.0 + b.abs()));
    }
    for (a, b) in a2_xla.iter().zip(&fwd.act_c1) {
        assert!((a - b).abs() < 2e-3 * (1.0 + b.abs()));
    }
}

#[test]
fn lenet_tail_artifacts_match_native() {
    let Some(mut reg) = registry() else { return };
    let params = lenet_params(13);
    let (x, y, _) = batch(8, 24);
    let (fwd, _) = lenet::forward(&params, &x, &y, 8);

    // tail c1: (a_fc2, fc3_w, fc3_b, y) -> (gw, gb)
    let exe = reg.get("lenet_tail_c1_b8").unwrap();
    let out = exe
        .run(&[
            ArgValue::F32(&fwd.act_c1),
            ArgValue::F32(&params[8]),
            ArgValue::F32(&params[9]),
            ArgValue::F32(&y),
        ])
        .unwrap();
    let native = lenet::tail_grads(&params, &fwd, &y, 1, 8);
    for ((_, g_native), o) in native.iter().zip(out.iter()) {
        for (a, b) in o.as_f32().unwrap().iter().zip(g_native) {
            assert!((a - b).abs() < 1e-4 + 2e-3 * b.abs(), "tail1 {a} vs {b}");
        }
    }

    // tail c2
    let exe = reg.get("lenet_tail_c2_b8").unwrap();
    let out = exe
        .run(&[
            ArgValue::F32(&fwd.act_c2),
            ArgValue::F32(&params[6]),
            ArgValue::F32(&params[7]),
            ArgValue::F32(&params[8]),
            ArgValue::F32(&params[9]),
            ArgValue::F32(&y),
        ])
        .unwrap();
    let native = lenet::tail_grads(&params, &fwd, &y, 2, 8);
    for ((_, g_native), o) in native.iter().zip(out.iter()) {
        for (a, b) in o.as_f32().unwrap().iter().zip(g_native) {
            assert!((a - b).abs() < 1e-4 + 2e-3 * b.abs(), "tail2 {a} vs {b}");
        }
    }
}

#[test]
fn lenet_step_artifact_reduces_loss() {
    let Some(mut reg) = registry() else { return };
    let params = lenet_params(15);
    let (x, y, _) = batch(8, 26);
    let exe = reg.get("lenet_step_b8").unwrap();
    let lr = [0.05f32];
    let mut args: Vec<ArgValue> = params.iter().map(|p| ArgValue::F32(p)).collect();
    args.push(ArgValue::F32(&x));
    args.push(ArgValue::F32(&y));
    args.push(ArgValue::F32(&lr));
    let out = exe.run(&args).unwrap();
    assert_eq!(out.len(), 11);
    let loss0 = out[10].scalar_f32().unwrap();
    // feed updated params through the native engine
    let new_params: Vec<Vec<f32>> = out[..10]
        .iter()
        .map(|o| o.as_f32().unwrap().to_vec())
        .collect();
    let (f1, _) = lenet::forward(&new_params, &x, &y, 8);
    assert!(f1.loss < loss0, "{loss0} -> {}", f1.loss);
}

#[test]
fn lenet_int8_artifact_matches_native_bit_for_bit() {
    let Some(mut reg) = registry() else { return };
    let ws = lenet8::init_params(17, 32);
    let d = elasticzo::data::synth_mnist::generate(8, 28);
    let xq = lenet8::quantize_input(&d.x, 8);

    let exe = reg.get("lenet_int8_fwd_b8").unwrap();
    let exps: Vec<[i32; 1]> = ws.iter().map(|w| [w.exp]).collect();
    let x_exp = [xq.exp];
    let mut args: Vec<ArgValue> = ws.iter().map(|w| ArgValue::I8(&w.data)).collect();
    for e in &exps {
        args.push(ArgValue::I32(e));
    }
    args.push(ArgValue::I8(&xq.data));
    args.push(ArgValue::I32(&x_exp));
    let out = exe.run(&args).unwrap();
    let logits_xla = out[0].as_i8().unwrap();
    let s_xla = out[1].as_i32().unwrap()[0];

    let fwd = lenet8::forward(&ws, &xq, 8);
    assert_eq!(s_xla, fwd.logits.exp, "exponent mismatch");
    assert_eq!(logits_xla, &fwd.logits.data[..], "int8 logits must be bit-identical");
}

#[test]
fn registry_lists_and_caches() {
    let Some(mut reg) = registry() else { return };
    assert!(reg.names().len() >= 10);
    assert_eq!(reg.loaded_count(), 0);
    reg.get("lenet_fwd_b8").unwrap();
    reg.get("lenet_fwd_b8").unwrap();
    assert_eq!(reg.loaded_count(), 1);
}
