//! Durability acceptance tests: EZOC v2 checkpoint round-trips
//! (property-tested), v1 forward compatibility, and the headline
//! resume-parity guarantee — a run checkpointed at epoch k and resumed
//! matches an uninterrupted run EXACTLY (same params, same metrics),
//! for both the FP32 and the INT8 stacks, because minibatch order is a
//! pure function of `(seed, epoch)` and ZO perturbations of
//! `(seed, step)`.

use elasticzo::config::Config;
use elasticzo::coordinator::checkpoint::{
    self, CkptTensor, TensorData, TrainState,
};
use elasticzo::coordinator::control::{ProgressSink, StopFlag};
use elasticzo::coordinator::{Model, ParamSet, TrainSpec};
use elasticzo::launch;
use elasticzo::util::prop;

fn tmp(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("ezo_resume_{name}_{}", std::process::id()))
        .display()
        .to_string()
}

#[test]
fn v2_checkpoint_roundtrip_property() {
    prop::cases(20, |rng, case| {
        let ntensors = 1 + (rng.next_u64() % 4) as usize;
        let tensors: Vec<CkptTensor> = (0..ntensors)
            .map(|i| {
                let rank = 1 + (rng.next_u64() % 3) as usize;
                let dims: Vec<usize> =
                    (0..rank).map(|_| 1 + (rng.next_u64() % 5) as usize).collect();
                let numel: usize = dims.iter().product();
                let name = format!("tensor_{i}");
                if rng.bernoulli(0.5) {
                    CkptTensor {
                        name,
                        dims,
                        data: TensorData::F32((0..numel).map(|_| rng.normal()).collect()),
                    }
                } else {
                    CkptTensor {
                        name,
                        dims,
                        data: TensorData::I8 {
                            data: (0..numel)
                                .map(|_| rng.uniform_i32(-128, 127) as i8)
                                .collect(),
                            exp: rng.uniform_i32(-20, 20),
                        },
                    }
                }
            })
            .collect();
        let state = (case % 2 == 0).then(|| TrainState {
            epochs_done: (rng.next_u64() % 100) as usize,
            step: rng.next_u64() % 1_000_000,
            best_test_acc: rng.uniform(),
            last_test_loss: rng.normal().abs(),
            last_test_acc: rng.uniform(),
            spec: TrainSpec::default().to_json(),
            elastic: None,
        });

        let path = tmp(&format!("prop_{case}"));
        checkpoint::save_with_state(&path, &tensors, state.as_ref()).unwrap();
        let (back_tensors, back_state) = checkpoint::load_full(&path).unwrap();
        assert_eq!(back_tensors, tensors, "case {case}: tensors must round-trip bitwise");
        assert_eq!(back_state, state, "case {case}: training state must round-trip");
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn v1_files_remain_loadable() {
    // a v1 file written byte-by-byte (the legacy writer no longer
    // exists): same tensor section, no version-2 trailer
    let mut b: Vec<u8> = Vec::new();
    b.extend_from_slice(b"EZOC");
    b.extend_from_slice(&1u32.to_le_bytes());
    b.extend_from_slice(&2u32.to_le_bytes()); // two tensors
    for (name, vals) in [("conv1_w", vec![0.5f32, -1.5]), ("fc_b", vec![3.25f32, 0.0])] {
        b.extend_from_slice(&(name.len() as u32).to_le_bytes());
        b.extend_from_slice(name.as_bytes());
        b.push(0); // f32
        b.extend_from_slice(&0i32.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes()); // rank 1
        b.extend_from_slice(&(vals.len() as u64).to_le_bytes());
        for v in &vals {
            b.extend_from_slice(&v.to_le_bytes());
        }
    }
    let path = tmp("v1");
    std::fs::write(&path, &b).unwrap();

    let (tensors, state) = checkpoint::load_full(&path).unwrap();
    assert!(state.is_none(), "v1 files have no training state");
    assert_eq!(tensors.len(), 2);
    assert_eq!(tensors[0].name, "conv1_w");
    assert_eq!(tensors[0].data, TensorData::F32(vec![0.5, -1.5]));
    assert_eq!(tensors[1].name, "fc_b");
    std::fs::remove_file(&path).ok();
}

fn parity_cfg(precision: &str, epochs: usize, save: &str) -> Config {
    let mut cfg = Config::default();
    cfg.set("engine", "native").unwrap();
    cfg.set("method", "cls1").unwrap();
    cfg.set("precision", precision).unwrap();
    cfg.set("epochs", &epochs.to_string()).unwrap();
    cfg.set("batch", "16").unwrap();
    cfg.set("train_n", "64").unwrap();
    cfg.set("test_n", "32").unwrap();
    cfg.set("seed", "7").unwrap();
    cfg.set("save", save).unwrap();
    cfg.validate().unwrap();
    cfg
}

/// Stop the run right after epoch `k` reports (the loop exits at the
/// top of epoch k+1, after epoch k's cadence snapshot was written).
fn stop_after_epoch(k: usize) -> (StopFlag, ProgressSink) {
    let stop = StopFlag::new();
    let stop2 = stop.clone();
    let sink = ProgressSink::new(move |e| {
        if e.epoch == k {
            stop2.request_stop();
        }
    });
    (stop, sink)
}

/// Train `epochs` straight; train a second lineage interrupted after
/// `interrupt_after + 1` completed epochs and resumed to the end; both
/// final checkpoints (params AND training state) must match bitwise.
fn assert_resume_parity(precision: &str, epochs: usize, interrupt_after: usize) {
    let path_a = tmp(&format!("straight_{precision}", precision = precision.replace('*', "s")));
    let path_b = tmp(&format!("resumed_{precision}", precision = precision.replace('*', "s")));

    // lineage A: uninterrupted
    let cfg_a = parity_cfg(precision, epochs, &path_a);
    let la = launch::run(&cfg_a, StopFlag::default(), ProgressSink::default()).unwrap();
    assert!(!la.result.stopped);
    assert_eq!(la.result.history.epochs.len(), epochs);

    // lineage B: interrupted mid-run…
    let cfg_b = parity_cfg(precision, epochs, &path_b);
    let (stop, sink) = stop_after_epoch(interrupt_after);
    let lb = launch::run(&cfg_b, stop, sink).unwrap();
    assert!(lb.result.stopped, "{precision}: run must stop early");
    let (_, state) = checkpoint::load_full(&path_b).unwrap();
    let state = state.expect("cadence snapshot carries training state");
    assert_eq!(
        state.epochs_done,
        interrupt_after + 1,
        "{precision}: the cancelled run must persist its last completed epoch"
    );

    // …and resumed to completion
    let mut cfg_r = parity_cfg(precision, epochs, &path_b);
    cfg_r.set("resume", &path_b).unwrap();
    cfg_r.validate().unwrap();
    let lr = launch::run(&cfg_r, StopFlag::default(), ProgressSink::default()).unwrap();
    assert_eq!(lr.resumed_from, Some(interrupt_after + 1));
    assert!(!lr.result.stopped);
    assert_eq!(
        lr.result.history.epochs.len(),
        epochs - (interrupt_after + 1),
        "{precision}: resume must run exactly the remaining epochs"
    );

    // the resumed lineage's final epoch must equal the straight run's
    // final epoch EXACTLY (same losses, same accuracies)
    let ea = la.result.history.epochs.last().unwrap();
    let eb = lr.result.history.epochs.last().unwrap();
    assert_eq!(ea.epoch, eb.epoch, "{precision}");
    assert_eq!(ea.train_loss, eb.train_loss, "{precision}: train loss must match bitwise");
    assert_eq!(ea.test_loss, eb.test_loss, "{precision}: test loss must match bitwise");
    assert_eq!(ea.train_acc, eb.train_acc, "{precision}: train acc must match");
    assert_eq!(ea.test_acc, eb.test_acc, "{precision}: test acc must match");

    // and so must the final checkpoints: identical params + loop state
    let (ta, sa) = checkpoint::load_full(&path_a).unwrap();
    let (tb, sb) = checkpoint::load_full(&path_b).unwrap();
    assert_eq!(ta, tb, "{precision}: final params must be bit-identical");
    let (sa, sb) = (sa.unwrap(), sb.unwrap());
    assert_eq!(sa.epochs_done, epochs);
    assert_eq!(sa.epochs_done, sb.epochs_done);
    assert_eq!(sa.step, sb.step, "{precision}: ZO stream positions must match");
    assert_eq!(sa.best_test_acc, sb.best_test_acc, "{precision}");
    assert_eq!(sa.last_test_loss, sb.last_test_loss, "{precision}");

    for p in [path_a, path_b] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn fp32_resume_matches_uninterrupted_run_exactly() {
    // 2 epochs + interrupt + 3 resumed == 5 straight
    assert_resume_parity("fp32", 5, 1);
}

#[test]
fn int8_resume_matches_uninterrupted_run_exactly() {
    assert_resume_parity("int8", 4, 1);
}

#[test]
fn int8_star_resume_matches_uninterrupted_run_exactly() {
    // the integer-only sign path shares the same durability machinery
    assert_resume_parity("int8*", 4, 1);
}

/// An elastic-boundary config whose huge `eps` makes every eval a
/// plateau: with patience 1 the controller is guaranteed to deepen the
/// boundary at epochs 1 and 2, giving a deterministic mid-run
/// k-schedule to replay.
fn elastic_cfg(save: &str, epochs: usize) -> Config {
    let mut cfg = Config::default();
    cfg.set("engine", "native").unwrap();
    cfg.set("method", "full-zo").unwrap();
    cfg.set("boundary", "elastic:0-2").unwrap();
    cfg.set("elastic-patience", "1").unwrap();
    cfg.set("elastic-eps", "100").unwrap();
    cfg.set("epochs", &epochs.to_string()).unwrap();
    cfg.set("batch", "16").unwrap();
    cfg.set("train_n", "64").unwrap();
    cfg.set("test_n", "32").unwrap();
    cfg.set("seed", "7").unwrap();
    cfg.set("save", save).unwrap();
    cfg.validate().unwrap();
    cfg
}

#[test]
fn elastic_boundary_resume_matches_from_checkpoint_and_journal() {
    use elasticzo::serve::{journal, JobSpec};
    use elasticzo::util::json::{self, Value};

    let epochs = 5;
    let path_a = tmp("elastic_straight");
    let path_b = tmp("elastic_ckpt");
    let path_c = tmp("elastic_journal");

    // lineage A: uninterrupted. The controller MUST have moved the
    // boundary mid-run, and the per-epoch audit trail records each k.
    let la = launch::run(&elastic_cfg(&path_a, epochs), StopFlag::default(), ProgressSink::default())
        .unwrap();
    let (ta, sa) = checkpoint::load_full(&path_a).unwrap();
    let sa = sa.unwrap();
    let ea = sa.elastic.as_ref().expect("elastic trailer in the final checkpoint");
    assert!(!ea.events.is_empty(), "the plateau controller must have moved the boundary");
    let ks: Vec<_> = la.result.history.epochs.iter().map(|e| e.bp_tail).collect();
    assert!(ks.iter().any(|k| *k != ks[0]), "bp_tail must change mid-run: {ks:?}");

    // lineage B: interrupted right after the FIRST boundary change
    // (epoch 1's cadence snapshot carries the controller state)...
    let (stop, sink) = stop_after_epoch(1);
    let lb = launch::run(&elastic_cfg(&path_b, epochs), stop, sink).unwrap();
    assert!(lb.result.stopped);
    let (_, sb) = checkpoint::load_full(&path_b).unwrap();
    let eb = sb.unwrap().elastic.expect("interrupted trailer carries controller state");
    assert!(!eb.events.is_empty(), "interrupt must land after the first move");

    // ...and resumed from the checkpoint: the k-schedule continues
    // (including the SECOND move, post-resume) and the final params +
    // TrainState match the straight run bitwise
    let mut cfg_r = elastic_cfg(&path_b, epochs);
    cfg_r.set("resume", &path_b).unwrap();
    cfg_r.validate().unwrap();
    launch::run(&cfg_r, StopFlag::default(), ProgressSink::default()).unwrap();
    let (tb, sb) = checkpoint::load_full(&path_b).unwrap();
    assert_eq!(ta, tb, "checkpoint resume: final params must be bit-identical");
    assert_eq!(Some(sa.clone()), sb, "checkpoint resume: TrainState (incl. elastic) must match");

    // lineage C: same interruption, but the serve JOURNAL does the
    // resuming — replay folds the event stream back into a job,
    // prepare_requeue arms resume from the cadence snapshot, and the
    // requeued config runs to the same final state
    let (stop, sink) = stop_after_epoch(1);
    launch::run(&elastic_cfg(&path_c, epochs), stop, sink).unwrap();
    let spec = JobSpec::new(elastic_cfg(&path_c, epochs));
    let jpath = tmp("elastic_journal_log");
    let lines = [
        json::to_string(&Value::obj(vec![
            ("event", Value::str("submit")),
            ("id", Value::num(1.0)),
            ("ts", Value::num(0.0)),
            ("spec", spec.to_json()),
        ])),
        json::to_string(&Value::obj(vec![
            ("event", Value::str("start")),
            ("id", Value::num(1.0)),
            ("agent", Value::num(7.0)),
        ])),
        // the mid-run move's audit record: folds to a no-op (the
        // k-schedule rides in the checkpoint trailer, not the spec)
        json::to_string(&Value::obj(vec![
            ("event", Value::str("boundary")),
            ("id", Value::num(1.0)),
            ("epoch", Value::num(1.0)),
            ("k", Value::num(1.0)),
            ("reason", Value::str("elastic")),
        ])),
        json::to_string(&Value::obj(vec![
            ("event", Value::str("requeue")),
            ("id", Value::num(1.0)),
        ])),
    ];
    std::fs::write(&jpath, lines.join("\n") + "\n").unwrap();
    let mut jobs = journal::replay(&jpath).unwrap();
    assert_eq!(jobs.len(), 1);
    let job = &mut jobs[0];
    assert_eq!(
        job.spec.config.method,
        elasticzo::coordinator::Method::FULL_ZO,
        "an audit-only 'elastic' event must NOT rewrite the spec"
    );
    assert!(journal::prepare_requeue(job), "queued job must be schedulable");
    assert_eq!(
        job.spec.config.resume.as_deref(),
        Some(path_c.as_str()),
        "replay must arm resume from the cadence snapshot"
    );
    launch::run(&job.spec.config, StopFlag::default(), ProgressSink::default()).unwrap();
    let (tc, sc) = checkpoint::load_full(&path_c).unwrap();
    assert_eq!(ta, tc, "journal replay: final params must be bit-identical");
    assert_eq!(Some(sa), sc, "journal replay: TrainState (incl. elastic) must match");

    for p in [path_a, path_b, path_c, jpath] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn resume_rejects_a_different_spec() {
    let path = tmp("mismatch");
    let cfg = parity_cfg("fp32", 3, &path);
    launch::run(&cfg, StopFlag::default(), ProgressSink::default()).unwrap();

    // same checkpoint, different seed ⇒ a different run: hard error
    let mut other = parity_cfg("fp32", 3, &path);
    other.set("seed", "8").unwrap();
    other.set("resume", &path).unwrap();
    other.validate().unwrap();
    let err = launch::run(&other, StopFlag::default(), ProgressSink::default())
        .unwrap_err()
        .to_string();
    assert!(err.contains("seed"), "error must name the differing key: {err}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn resume_rejects_params_only_checkpoints() {
    let path = tmp("params_only");
    checkpoint::save_params(&path, &ParamSet::init(Model::LeNet, 3)).unwrap();
    let mut cfg = parity_cfg("fp32", 3, &tmp("params_only_save"));
    cfg.set("resume", &path).unwrap();
    cfg.validate().unwrap();
    let err = launch::run(&cfg, StopFlag::default(), ProgressSink::default())
        .unwrap_err()
        .to_string();
    assert!(err.contains("no training state"), "{err}");
    std::fs::remove_file(&path).ok();
}
