//! Failure injection: the runtime and coordinator must fail loudly and
//! legibly on broken inputs — bad manifests, corrupt HLO, ABI
//! mismatches, invalid configs.

use elasticzo::config::Config;
use elasticzo::runtime::Manifest;
use elasticzo::util::cli::Args;
use std::path::PathBuf;

fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ezo_fail_{name}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_mentions_make_artifacts() {
    let d = tmp_dir("nomanifest");
    let err = Manifest::load(&d).unwrap_err().to_string();
    assert!(err.contains("make artifacts"), "{err}");
    std::fs::remove_dir_all(d).ok();
}

#[test]
fn malformed_manifest_rejected() {
    let d = tmp_dir("badjson");
    std::fs::write(d.join("manifest.json"), "{not json").unwrap();
    assert!(Manifest::load(&d).is_err());
    std::fs::write(d.join("manifest.json"), r#"{"version": 1}"#).unwrap();
    assert!(Manifest::load(&d).is_err()); // missing entries
    std::fs::remove_dir_all(d).ok();
}

#[cfg(feature = "xla")]
#[test]
fn corrupt_hlo_text_rejected() {
    use elasticzo::runtime::{ArtifactSpec, LoadedArtifact};
    let client = match xla_client() {
        Some(c) => c,
        None => return,
    };
    let d = tmp_dir("badhlo");
    let path = d.join("bad.hlo.txt");
    std::fs::write(&path, "HloModule garbage !!! not hlo").unwrap();
    let spec = ArtifactSpec {
        name: "bad".into(),
        path: "bad.hlo.txt".into(),
        inputs: vec![],
        outputs: vec![],
        meta: elasticzo::util::json::Value::Null,
    };
    assert!(LoadedArtifact::load(&client, spec, &path).is_err());
    std::fs::remove_dir_all(d).ok();
}

#[cfg(feature = "xla")]
fn xla_client() -> Option<xla::PjRtClient> {
    xla::PjRtClient::cpu().ok()
}

#[cfg(feature = "xla")]
#[test]
fn abi_mismatch_rejected_before_execution() {
    // wrong arg count / wrong shape / wrong dtype must be caught by the
    // marshalling layer, not by XLA
    let Ok(mut reg) = elasticzo::runtime::Registry::open_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let Ok(exe) = reg.get("lenet_fwd_b8") else { return };
    // 0 args instead of 12
    assert!(exe.run(&[]).is_err());
    // right count, wrong shapes
    let junk = vec![0.0f32; 3];
    let args: Vec<elasticzo::runtime::ArgValue> =
        (0..12).map(|_| elasticzo::runtime::ArgValue::F32(&junk)).collect();
    let err = exe.run(&args).unwrap_err().to_string();
    assert!(err.contains("mismatch"), "{err}");
}

#[test]
fn invalid_configs_rejected_with_context() {
    let bad = [
        vec!["--epochs", "0"],
        vec!["--batch", "0"],
        vec!["--eps", "-1"],
        vec!["--b-zo", "9"],
        vec!["--model", "resnet"],
        vec!["--method", "cls3"],
        vec!["--precision", "fp16"],
        // the generalized boundary validates against the model's
        // classifier stack, and elastic knobs against their range
        vec!["--method", "bp-tail=9"],
        vec!["--bp-tail", "4", "--engine", "native"],
        vec!["--boundary", "rubber"],
        vec!["--boundary", "elastic:2-1"],
        vec!["--elastic-patience", "2"], // orphan knob: needs boundary=elastic
        vec!["--method", "full-bp", "--boundary", "elastic:0-2", "--engine", "native"],
        // kernel / structured-perturbation knobs: every unsupported
        // combination must die at config time, not deep in a session
        vec!["--kernels", "maybe"],
        vec!["--sparse-block", "64", "--kernels", "false"],
        vec!["--sparse-block", "64", "--precision", "int8"],
        vec!["--sparse-block", "64", "--method", "full-bp"],
        vec!["--sparse-block", "64", "--sparse-keep", "0"],
        vec!["--sparse-block", "64", "--sparse-keep", "1.5"],
        vec!["--sparse-block", "64", "--method", "full-zo", "--dp", "2"],
    ];
    for case in bad {
        let args = Args::parse(case.iter().map(|s| s.to_string()));
        assert!(Config::from_args(&args).is_err(), "should reject {case:?}");
    }
}

#[test]
fn dp_rejects_nonzero_and_elastic_boundaries() {
    // dp replicas replay the shared RNG stream over the WHOLE net, so
    // anything but bp-tail=0 (and any elastic range) must die at
    // config time with an error that names dp
    for case in [
        vec!["--dp", "2", "--engine", "native", "--method", "cls1"],
        vec!["--dp", "2", "--engine", "native", "--method", "bp-tail=1"],
        vec!["--dp", "2", "--engine", "native", "--method", "full-zo", "--boundary", "elastic:0-2"],
    ] {
        let args = Args::parse(case.iter().map(|s| s.to_string()));
        let err = Config::from_args(&args).unwrap_err().to_string();
        assert!(err.contains("dp"), "error must name dp: {err} ({case:?})");
    }
    // bp-tail=0 IS full-zo — dp accepts the generalized spelling
    let args = Args::parse(
        ["--dp", "2", "--engine", "native", "--method", "bp-tail=0"]
            .iter()
            .map(|s| s.to_string()),
    );
    assert!(Config::from_args(&args).is_ok(), "bp-tail=0 is the full-zo alias");
}

#[test]
fn checkpoint_truncation_detected() {
    use elasticzo::coordinator::{checkpoint, Model, ParamSet};
    let p = ParamSet::init(Model::LeNet, 1);
    let path = std::env::temp_dir().join(format!("ezo_trunc_{}.ckpt", std::process::id()));
    checkpoint::save_params(&path, &p).unwrap();
    // truncate the file and expect a read error
    let full = std::fs::read(&path).unwrap();
    std::fs::write(&path, &full[..full.len() / 2]).unwrap();
    let mut q = ParamSet::init(Model::LeNet, 2);
    assert!(checkpoint::load_params(&path, &mut q).is_err());
    std::fs::remove_file(path).ok();
}
