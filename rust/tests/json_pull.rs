//! Property tests for the zero-alloc JSON pull parser
//! (`util::json::Reader`): differential round-trips against the
//! recursive reference parser over generated documents (nesting,
//! escapes, unicode, i64/f64 edge numbers), torn-input strictness
//! (no prefix of a document ever parses), and an allocation-counter
//! proof that visiting every `SERVE_API.md` example allocates nothing
//! once the scratch buffer is warm.

use elasticzo::metrics::alloc::{alloc_count, measure_scope, TrackedAlloc};
use elasticzo::rng::Rng64;
use elasticzo::util::json::{self, Reader, Value};
use elasticzo::util::prop;
use std::collections::BTreeMap;
use std::sync::Mutex;

// The allocation counters are process-global, so this binary installs
// the tracked allocator and serializes its tests.
#[global_allocator]
static ALLOC: TrackedAlloc = TrackedAlloc;

static SERIAL: Mutex<()> = Mutex::new(());

/// f64 values whose textual round-trip exercises the number grammar's
/// edges: integer collapsing, denormals, huge exponents, i64 bounds.
const EDGE_NUMS: &[f64] = &[
    0.0,
    -1.0,
    1.5,
    -2.25,
    0.1,
    1e-9,
    1e9 + 7.0,
    1e308,
    5e-324,
    9.007199254740992e15, // 2^53: first integer the i64 fast path skips
    9.223372036854776e18, // i64::MAX neighborhood
    -9.223372036854776e18,
];

fn gen_string(rng: &mut Rng64) -> String {
    // escapes, control bytes, multi-byte unicode, and plain ASCII
    const PALETTE: &[char] = &[
        'a', 'B', '7', ' ', '"', '\\', '/', '\n', '\t', '\r', '\u{8}', '\u{c}', '\u{1}',
        '\u{1f}', 'é', 'λ', '中', '🦀',
    ];
    let len = rng.uniform_i32(0, 12) as usize;
    (0..len).map(|_| PALETTE[rng.uniform_i32(0, PALETTE.len() as i32 - 1) as usize]).collect()
}

fn gen_num(rng: &mut Rng64) -> f64 {
    match rng.uniform_i32(0, 3) {
        0 => EDGE_NUMS[rng.uniform_i32(0, EDGE_NUMS.len() as i32 - 1) as usize],
        1 => rng.uniform_i32(i32::MIN, i32::MAX) as f64,
        2 => rng.uniform_f64() * 2e3 - 1e3,
        _ => rng.uniform_f64(),
    }
}

fn gen_value(rng: &mut Rng64, depth: usize) -> Value {
    // containers get rarer with depth so documents stay small
    let hi = if depth >= 4 { 3 } else { 5 };
    match rng.uniform_i32(0, hi) {
        0 => Value::Null,
        1 => Value::Bool(rng.bernoulli(0.5)),
        2 => Value::Num(gen_num(rng)),
        3 => Value::Str(gen_string(rng)),
        4 => {
            let n = rng.uniform_i32(0, 4) as usize;
            Value::Arr((0..n).map(|_| gen_value(rng, depth + 1)).collect())
        }
        _ => {
            let n = rng.uniform_i32(0, 4) as usize;
            let mut m = BTreeMap::new();
            for i in 0..n {
                // suffix keeps generated keys distinct even when the
                // palette collides
                m.insert(format!("{}#{i}", gen_string(rng)), gen_value(rng, depth + 1));
            }
            Value::Obj(m)
        }
    }
}

#[test]
fn pull_parser_round_trips_generated_documents() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    prop::cases(300, |rng, case| {
        let doc = gen_value(rng, 0);
        let compact = json::to_string(&doc);
        let pretty = json::to_string_pretty(&doc);

        let reference = json::parse(&compact)
            .unwrap_or_else(|e| panic!("case {case}: reference parse failed: {e} on {compact}"));
        let pulled = json::parse_pull(&compact)
            .unwrap_or_else(|e| panic!("case {case}: pull parse failed: {e} on {compact}"));
        assert_eq!(pulled, reference, "case {case}: trees diverged on {compact}");
        assert_eq!(pulled, doc, "case {case}: round-trip lost information on {compact}");

        // whitespace-heavy spelling of the same document
        let pulled_pretty = json::parse_pull(&pretty)
            .unwrap_or_else(|e| panic!("case {case}: pretty pull failed: {e} on {pretty}"));
        assert_eq!(pulled_pretty, reference, "case {case}: pretty diverged");

        // and re-serialization agrees byte-for-byte
        assert_eq!(json::to_string(&pulled), compact, "case {case}");
    });
}

#[test]
fn torn_prefixes_never_parse() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    prop::cases_seeded(0x70D4, 120, |rng, case| {
        // container root: no strict prefix can be a complete document,
        // so a torn read buffer must error, never half-succeed
        let doc = match rng.uniform_i32(0, 1) {
            0 => Value::Arr(vec![gen_value(rng, 1), gen_value(rng, 1)]),
            _ => {
                let mut m = BTreeMap::new();
                m.insert("k".to_string(), gen_value(rng, 1));
                Value::Obj(m)
            }
        };
        let text = json::to_string(&doc);
        for (cut, _) in text.char_indices().skip(1) {
            let torn = &text[..cut];
            assert!(
                json::parse_pull(torn).is_err(),
                "case {case}: torn prefix parsed: {torn}"
            );
            assert!(
                json::parse(torn).is_err(),
                "case {case}: reference accepted torn prefix: {torn}"
            );
        }
        assert!(json::parse_pull(&text).is_ok(), "case {case}: full doc rejected: {text}");
    });
}

#[test]
fn i64_f64_edge_numbers_agree_with_reference() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    for text in [
        "9223372036854775807",  // i64::MAX literal
        "-9223372036854775808", // i64::MIN literal
        "18446744073709551615", // u64::MAX: overflows into f64 like the reference
        "1e308",
        "-1e308",
        "5e-324",
        "2.2250738585072014e-308",
        "0.30000000000000004",
        "-0",
        "1E+2",
        "120e-1",
        // shared lenient spellings: both scanners defer to Rust's f64
        // grammar for the digits they consume
        "01",
        "1.",
    ] {
        let a = json::parse(text).unwrap_or_else(|e| panic!("reference on {text}: {e}"));
        let b = json::parse_pull(text).unwrap_or_else(|e| panic!("pull on {text}: {e}"));
        assert_eq!(a, b, "parsers diverged on {text}");
        assert_eq!(json::to_string(&a), json::to_string(&b), "rendering diverged on {text}");
    }
    // malformed numbers fail (trailing garbage, bare signs, hex)
    for text in [".5", "1e", "+1", "--1", "0x10", "1e5x"] {
        assert!(json::parse_pull(text).is_err(), "pull accepted {text}");
        assert!(json::parse(text).is_err(), "reference accepted {text}");
    }
}

fn serve_api_examples() -> Vec<String> {
    let md = std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/docs/SERVE_API.md"))
        .expect("read SERVE_API.md");
    let mut out = Vec::new();
    let mut cur: Option<String> = None;
    for line in md.lines() {
        match cur.as_mut() {
            Some(buf) => {
                if line.trim_start().starts_with("```") {
                    out.push(cur.take().unwrap());
                } else {
                    buf.push_str(line);
                    buf.push('\n');
                }
            }
            None => {
                if line.trim_start() == "```json" {
                    cur = Some(String::new());
                }
            }
        }
    }
    assert!(out.len() >= 10, "SERVE_API.md lost its JSON examples ({} found)", out.len());
    out
}

/// Visit every token of `text`, reusing `scratch`; returns the token
/// count and the scratch buffer for the next document.
fn visit_all(text: &str, scratch: String) -> (usize, String) {
    let mut r = Reader::with_scratch(text, scratch);
    let mut toks = 0usize;
    while let Some(_t) = r.next_token().expect("valid example") {
        toks += 1;
    }
    (toks, r.into_scratch())
}

#[test]
fn visiting_every_serve_api_example_allocates_nothing_once_warm() {
    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let examples = serve_api_examples();

    // warm-up pass sizes the shared scratch buffer (and proves every
    // example is valid under the pull grammar)
    let mut scratch = String::new();
    let mut warm_toks = 0usize;
    for ex in &examples {
        let (n, s) = visit_all(ex, scratch);
        warm_toks += n;
        scratch = s;
    }
    assert!(warm_toks > 100, "examples should be non-trivial: {warm_toks} tokens");

    // measured pass: same documents, recycled scratch — zero heap
    // traffic. Retry a few times in case an unrelated runtime thread
    // allocates mid-window; a genuinely allocating parser fails every
    // attempt.
    let mut last = (0u64, 0usize);
    for _ in 0..3 {
        let before = alloc_count();
        let (cold_toks, stats) = measure_scope(|| {
            let mut s = std::mem::take(&mut scratch);
            let mut toks = 0usize;
            for ex in &examples {
                let (n, back) = visit_all(ex, s);
                toks += n;
                s = back;
            }
            scratch = s;
            toks
        });
        let delta = alloc_count() - before;
        assert_eq!(cold_toks, warm_toks, "warm pass saw different tokens");
        if delta == 0 && stats.peak_net_bytes == 0 {
            return;
        }
        last = (delta, stats.peak_net_bytes);
    }
    panic!(
        "visiting parse allocated: {} allocations, {} peak net bytes",
        last.0, last.1
    );
}
