//! Data-parallel e2e: ONE job trained by N agents over the
//! seed-compressed `/cluster/dp/*` wire must land on EXACTLY the bits
//! a single-process run of the same spec produces — the whole point of
//! shipping `(step, seed, scalar)` tuples instead of gradients is that
//! every replica (and the local reference) walks one identical f32
//! trajectory. The second test kills a replica mid-run and checks the
//! surviving quorum absorbs its shards and still finishes on the same
//! bits.

use elasticzo::coordinator::checkpoint;
use elasticzo::coordinator::control::{ProgressSink, StopFlag};
use elasticzo::launch;
use elasticzo::serve::{
    request, Agent, AgentHandle, AgentOptions, ClusterOptions, ServeOptions, Server,
};
use elasticzo::util::json::Value;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

const LONG: Duration = Duration::from_secs(300);

fn start_coordinator(lease_ms: u64) -> (String, JoinHandle<()>) {
    let server = Server::bind(&ServeOptions {
        port: 0,
        workers: 0, // pure coordinator: replicas are the only compute
        queue_cap: 8,
        journal: None,
        cluster: Some(ClusterOptions { lease_ms }),
        ..Default::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || server.run().unwrap());
    (addr, h)
}

fn spawn_agent(addr: &str, name: &str) -> AgentHandle {
    Agent::spawn(AgentOptions {
        coordinator: addr.to_string(),
        capacity: 1,
        name: name.to_string(),
        poll_ms: 50,
        max_poll_failures: 40,
        mem_budget: None,
    })
    .unwrap()
}

fn shutdown(addr: &str, handle: JoinHandle<()>) {
    let (status, _) = request(addr, "POST", "/shutdown", None).unwrap();
    assert_eq!(status, 200);
    handle.join().unwrap();
}

fn submit(addr: &str, spec: &str) -> u64 {
    let body = elasticzo::util::json::parse(spec).unwrap();
    let (status, v) = request(addr, "POST", "/jobs", Some(&body)).unwrap();
    assert_eq!(status, 200, "submit failed: {}", elasticzo::util::json::to_string(&v));
    v.get("id").as_f64().unwrap() as u64
}

fn poll_until(addr: &str, id: u64, pred: impl Fn(&Value) -> bool, what: &str) -> Value {
    let t0 = Instant::now();
    loop {
        let (status, v) = request(addr, "GET", &format!("/jobs/{id}"), None).unwrap();
        assert_eq!(status, 200, "job {id} must exist");
        if pred(&v) {
            return v;
        }
        assert!(
            t0.elapsed() < LONG,
            "timed out waiting for {what} on job {id}; last: {}",
            elasticzo::util::json::to_string(&v)
        );
        std::thread::sleep(Duration::from_millis(30));
    }
}

/// The single-process dp reference: `launch::run` with `dp` set runs
/// the same N-shard world in one process (`DpLocalSession`) and must
/// produce the trajectory the distributed run commits.
fn run_reference(epochs: usize, seed: u64, train_n: usize, batch: usize, save: &str) {
    let mut cfg = elasticzo::config::Config::default();
    for (k, val) in [
        ("method", "full-zo"),
        ("precision", "fp32"),
        ("engine", "native"),
        ("test_n", "32"),
        ("dp", "2"),
        ("dp-aggregate", "mean"),
    ] {
        cfg.set(k, val).unwrap();
    }
    cfg.set("epochs", &epochs.to_string()).unwrap();
    cfg.set("seed", &seed.to_string()).unwrap();
    cfg.set("train_n", &train_n.to_string()).unwrap();
    cfg.set("batch", &batch.to_string()).unwrap();
    cfg.set("save", save).unwrap();
    cfg.validate().unwrap();
    let l = launch::run(&cfg, StopFlag::default(), ProgressSink::default()).unwrap();
    assert!(!l.result.stopped);
}

/// Compare the distributed dp checkpoint against the local reference:
/// tensors bit-identical, training-state trailer numerically identical
/// (the embedded spec JSON differs only in its save path).
fn assert_bit_identical(dp_ckpt: &str, ref_ckpt: &str, epochs: usize) {
    let (t_dp, s_dp) = checkpoint::load_full(dp_ckpt).unwrap();
    let (t_ref, s_ref) = checkpoint::load_full(ref_ckpt).unwrap();
    assert_eq!(t_dp, t_ref, "dp params must be bit-identical to the local reference");
    let s_dp = s_dp.expect("dp checkpoint carries training state");
    let s_ref = s_ref.expect("reference checkpoint carries training state");
    assert_eq!(s_dp.epochs_done, epochs);
    assert_eq!(s_dp.epochs_done, s_ref.epochs_done);
    assert_eq!(s_dp.step, s_ref.step, "ZO stream positions must match");
    assert_eq!(s_dp.best_test_acc, s_ref.best_test_acc);
    assert_eq!(s_dp.last_test_loss, s_ref.last_test_loss);
    assert_eq!(s_dp.last_test_acc, s_ref.last_test_acc);
}

#[test]
fn dp_two_replicas_bit_identical_to_local_reference() {
    let dir = std::env::temp_dir().join(format!("ezo_dp_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt_dp = dir.join("dp2.ckpt").display().to_string();
    let ckpt_ref = dir.join("dp2_ref.ckpt").display().to_string();
    std::fs::remove_file(&ckpt_dp).ok();
    std::fs::remove_file(&ckpt_ref).ok();

    let epochs = 3usize;
    let (addr, h) = start_coordinator(10_000);
    let a1 = spawn_agent(&addr, "replica-1");
    let a2 = spawn_agent(&addr, "replica-2");

    // strict quorum: with min_replicas = 2, losing a replica would
    // stall rather than degrade — nothing should be lost here
    let job = submit(
        &addr,
        &format!(
            r#"{{"name": "dp2", "method": "full-zo", "precision": "fp32",
                "engine": "native", "epochs": {epochs}, "batch": 16,
                "train_n": 64, "test_n": 32, "seed": 5,
                "dp": {{"replicas": 2, "aggregate": "mean", "min_replicas": 2}},
                "save": "{ckpt_dp}"}}"#
        ),
    );
    let v = poll_until(&addr, job, |v| v.get("state").as_str() == Some("done"), "dp job done");

    // every epoch reported exactly once, whichever replica posted it
    let history = v.get("history").as_arr().unwrap();
    assert_eq!(history.len(), epochs, "history must cover every epoch exactly once");
    for (i, e) in history.iter().enumerate() {
        assert_eq!(e.get("epoch").as_usize(), Some(i));
    }
    assert!(v.get("best_test_acc").as_f64().unwrap() > 0.0);

    a1.stop();
    a2.stop();
    shutdown(&addr, h);

    run_reference(epochs, 5, 64, 16, &ckpt_ref);
    assert_bit_identical(&ckpt_dp, &ckpt_ref, epochs);
    std::fs::remove_file(&ckpt_dp).ok();
    std::fs::remove_file(&ckpt_ref).ok();
}

#[test]
fn dp_replica_death_reshards_to_survivor_same_bits() {
    let dir = std::env::temp_dir().join(format!("ezo_dpkill_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt_dp = dir.join("dpkill.ckpt").display().to_string();
    let ckpt_ref = dir.join("dpkill_ref.ckpt").display().to_string();
    std::fs::remove_file(&ckpt_dp).ok();
    std::fs::remove_file(&ckpt_ref).ok();

    // long enough that the kill lands mid-run (release steps are ~2
    // orders of magnitude faster than debug ones)
    let epochs: usize = if cfg!(debug_assertions) { 12 } else { 60 };

    // short lease so the dead replica's shards free within seconds
    let (addr, h) = start_coordinator(1_500);
    let doomed = spawn_agent(&addr, "doomed");
    let survivor = spawn_agent(&addr, "survivor");

    // min_replicas = 1: one survivor may absorb the lost shard and
    // finish alone
    let job = submit(
        &addr,
        &format!(
            r#"{{"name": "dpkill", "method": "full-zo", "precision": "fp32",
                "engine": "native", "epochs": {epochs}, "batch": 32,
                "train_n": 128, "test_n": 32, "seed": 11,
                "dp": {{"replicas": 2, "aggregate": "mean", "min_replicas": 1}},
                "save": "{ckpt_dp}"}}"#
        ),
    );

    // let both replicas make real progress, then kill one cold: no
    // leave, no deregistration — only its lease expiry frees the shard
    poll_until(
        &addr,
        job,
        |v| v.get("epochs_done").as_usize().unwrap_or(0) >= 2,
        "two epochs with both replicas",
    );
    doomed.kill();

    let v = poll_until(
        &addr,
        job,
        |v| v.get("state").as_str() == Some("done"),
        "dp job finishing on the surviving quorum",
    );
    let history = v.get("history").as_arr().unwrap();
    assert_eq!(history.len(), epochs, "history must cover every epoch exactly once");
    for (i, e) in history.iter().enumerate() {
        assert_eq!(e.get("epoch").as_usize(), Some(i));
    }

    survivor.stop();
    shutdown(&addr, h);

    // resharding must not have bent the trajectory: same bits as an
    // undisturbed single-process run of the same spec
    run_reference(epochs, 11, 128, 32, &ckpt_ref);
    assert_bit_identical(&ckpt_dp, &ckpt_ref, epochs);
    std::fs::remove_file(&ckpt_dp).ok();
    std::fs::remove_file(&ckpt_ref).ok();
}
