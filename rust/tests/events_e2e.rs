//! End-to-end tests of the live-telemetry subsystem: SSE streams over
//! real sockets (`GET /jobs/{id}/events` replay+live, `GET /events`
//! firehose resume), the `repro watch` client path (`watch_job`), the
//! never-block-the-trainer lagged semantics, and the `?history_since=`
//! polling trim.

use elasticzo::config::Config;
use elasticzo::coordinator::metrics::EpochStats;
use elasticzo::serve::events::SseParser;
use elasticzo::serve::{
    request, watch_job, Agent, AgentHandle, AgentOptions, ClusterOptions, JobRegistry,
    JobSpec, Poll, ServeOptions, Server, WatchFrame,
};
use elasticzo::util::json::Value;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

const LONG: Duration = Duration::from_secs(300);

/// A quick multi-epoch job against the synthetic dataset.
fn quick_job(epochs: usize) -> String {
    format!(
        r#"{{"method": "full-zo", "precision": "fp32", "engine": "native",
             "epochs": {epochs}, "batch": 16, "train_n": 64, "test_n": 32, "seed": 3}}"#
    )
}

fn start_server(workers: usize) -> (String, JoinHandle<()>) {
    let server = Server::bind(&ServeOptions {
        port: 0,
        workers,
        queue_cap: 8,
        ..Default::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || server.run().unwrap());
    (addr, h)
}

fn shutdown(addr: &str, handle: JoinHandle<()>) {
    let (status, _) = request(addr, "POST", "/shutdown", None).unwrap();
    assert_eq!(status, 200);
    handle.join().unwrap();
}

fn submit(addr: &str, spec: &str) -> u64 {
    let body = elasticzo::util::json::parse(spec).unwrap();
    let (status, v) = request(addr, "POST", "/jobs", Some(&body)).unwrap();
    assert_eq!(status, 200, "submit failed: {}", elasticzo::util::json::to_string(&v));
    v.get("id").as_f64().unwrap() as u64
}

fn poll_until(addr: &str, id: u64, pred: impl Fn(&Value) -> bool, what: &str) -> Value {
    let t0 = Instant::now();
    loop {
        let (status, v) = request(addr, "GET", &format!("/jobs/{id}"), None).unwrap();
        assert_eq!(status, 200);
        if pred(&v) {
            return v;
        }
        assert!(
            t0.elapsed() < LONG,
            "timed out waiting for {what} on job {id}; last: {}",
            elasticzo::util::json::to_string(&v)
        );
        std::thread::sleep(Duration::from_millis(30));
    }
}

/// Epoch indices seen by a watcher, asserting each arrives exactly once.
fn collect_epochs(frames: &[WatchFrame]) -> Vec<usize> {
    let mut seen = Vec::new();
    for f in frames {
        if let WatchFrame::Epoch { stats, .. } = f {
            assert!(
                !seen.contains(&stats.epoch),
                "epoch {} delivered more than once (saw {seen:?})",
                stats.epoch
            );
            seen.push(stats.epoch);
        }
    }
    seen
}

#[test]
fn job_stream_replays_history_then_finishes_exactly_once() {
    let (addr, h) = start_server(1);
    let id = submit(&addr, &quick_job(4));
    // let at least one epoch land first, so the stream has history to
    // replay before it goes live
    poll_until(
        &addr,
        id,
        |v| v.get("epochs_done").as_usize().unwrap_or(0) >= 1,
        "first epoch",
    );

    let mut frames: Vec<WatchFrame> = Vec::new();
    let state = watch_job(&addr, id, |f| frames.push(f.clone())).unwrap();
    // `repro watch` exits 0 exactly when this returns Ok(terminal)
    assert_eq!(state.as_str(), "done");

    let epochs = collect_epochs(&frames);
    assert_eq!(epochs, vec![0, 1, 2, 3], "every epoch exactly once, in order");
    // the pre-connect epoch(s) arrived as replay frames
    assert!(
        frames
            .iter()
            .any(|f| matches!(f, WatchFrame::Epoch { replay: true, .. })),
        "connecting after epoch 0 must replay it"
    );
    // the stream ends on the terminal state transition
    assert!(matches!(
        frames.last(),
        Some(WatchFrame::State { state, .. }) if state == "done"
    ));
    shutdown(&addr, h);
}

#[test]
fn job_stream_goes_live_and_survives_cancel() {
    let (addr, h) = start_server(1);
    // far more epochs than will run: the watcher is guaranteed to be
    // connected while the job is still producing live events
    let id = submit(&addr, &quick_job(10000));
    poll_until(&addr, id, |v| v.get("state").as_str() == Some("running"), "running");

    let frames: Arc<Mutex<Vec<WatchFrame>>> = Arc::new(Mutex::new(Vec::new()));
    let f2 = frames.clone();
    let addr2 = addr.clone();
    let watcher = std::thread::spawn(move || {
        watch_job(&addr2, id, |f| f2.lock().unwrap().push(f.clone()))
    });

    // wait until the watcher has observed at least two epochs, then
    // cancel; the terminal `cancelled` frame must close the stream
    let t0 = Instant::now();
    while frames
        .lock()
        .unwrap()
        .iter()
        .filter(|f| matches!(f, WatchFrame::Epoch { .. }))
        .count()
        < 2
    {
        assert!(t0.elapsed() < LONG, "watcher saw no epochs");
        std::thread::sleep(Duration::from_millis(20));
    }
    let (status, _) = request(&addr, "POST", &format!("/jobs/{id}/cancel"), None).unwrap();
    assert_eq!(status, 200);

    let state = watcher.join().unwrap().unwrap();
    assert_eq!(state.as_str(), "cancelled");
    let frames = frames.lock().unwrap();
    // the job was mid-run at connect time: live (non-replay) epoch
    // frames must be present, and still exactly-once
    assert!(
        frames
            .iter()
            .any(|f| matches!(f, WatchFrame::Epoch { replay: false, .. })),
        "a running job must stream live epochs"
    );
    collect_epochs(&frames);
    shutdown(&addr, h);
}

fn start_coordinator() -> (String, JoinHandle<()>) {
    let server = Server::bind(&ServeOptions {
        port: 0,
        workers: 0, // pure coordinator: the job must run on the agent
        queue_cap: 8,
        cluster: Some(ClusterOptions { lease_ms: 10_000 }),
        ..Default::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || server.run().unwrap());
    (addr, h)
}

fn spawn_agent(addr: &str) -> AgentHandle {
    Agent::spawn(AgentOptions {
        coordinator: addr.to_string(),
        capacity: 1,
        name: "events-e2e".to_string(),
        poll_ms: 50,
        max_poll_failures: 40,
        mem_budget: None,
    })
    .unwrap()
}

#[test]
fn remote_agent_job_streams_identically_to_a_local_one() {
    let (addr, h) = start_coordinator();
    let agent = spawn_agent(&addr);
    let id = submit(&addr, &quick_job(3));

    // the remote epoch POSTs route through the same registry bus, so a
    // watcher cannot tell this job ran on an agent
    let mut frames: Vec<WatchFrame> = Vec::new();
    let state = watch_job(&addr, id, |f| frames.push(f.clone())).unwrap();
    assert_eq!(state.as_str(), "done");
    assert_eq!(collect_epochs(&frames), vec![0, 1, 2]);
    assert!(matches!(
        frames.last(),
        Some(WatchFrame::State { state, .. }) if state == "done"
    ));

    agent.stop();
    shutdown(&addr, h);
}

#[test]
fn stalled_subscriber_lags_instead_of_blocking_the_trainer() {
    // registry-level: record_epoch is exactly what a worker's
    // ProgressSink (and the cluster epoch POST) calls from the
    // training thread — it must never wait on a slow consumer
    let registry = JobRegistry::new();
    let id = registry.add(JobSpec::new(Config::default()));
    registry.claim(id, 0).unwrap();

    // the subscriber exists but never reads: a stalled `curl -N`
    let sub = registry.events().subscribe(Some(id), 4);
    let t0 = Instant::now();
    for e in 0..100 {
        registry.record_epoch(id, EpochStats { epoch: e, ..Default::default() });
    }
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "publishing 100 epochs into a stalled subscriber must not block"
    );
    // every epoch still landed in the job history (the trainer's view)
    let v = registry.job_json(id).unwrap();
    assert_eq!(v.get("epochs_done").as_usize(), Some(100));

    // the stalled consumer wakes up: explicit lagged marker first,
    // then only the newest `cap` events
    match sub.recv(Duration::from_secs(1)) {
        Poll::Lagged { next_seq } => assert!(next_seq > 0),
        other => panic!("expected a lagged marker, got {other:?}"),
    }
    let mut delivered = 0;
    while let Poll::Event(e) = sub.recv(Duration::from_millis(50)) {
        assert!(e.data.get("stats").get("epoch").as_usize().unwrap() >= 96);
        delivered += 1;
    }
    assert_eq!(delivered, 4, "only the buffer's worth of newest events survives");
}

#[test]
fn firehose_resumes_from_since_seq_over_http() {
    let (addr, h) = start_server(1);
    let id = submit(&addr, &quick_job(2));
    poll_until(&addr, id, |v| v.get("state").as_str() == Some("done"), "done");

    // a malformed resume point is a one-shot 400, not a stream
    let (status, v) = request(&addr, "GET", "/events?since_seq=abc", None).unwrap();
    assert_eq!(status, 400);
    assert!(v.get("error").as_str().unwrap().contains("since_seq"));

    // resume from the beginning: the ring still holds the whole run
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream
        .write_all(
            format!("GET /events?since_seq=0 HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")
                .as_bytes(),
        )
        .unwrap();

    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos;
        }
        let n = stream.read(&mut tmp).unwrap();
        assert!(n > 0, "no response");
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..header_end]).to_string();
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert!(head.contains("text/event-stream"), "{head}");

    let mut parser = SseParser::default();
    let mut frames = parser.push(&buf[header_end + 4..]);
    let mut epochs = Vec::new();
    let mut done = false;
    let t0 = Instant::now();
    while !done {
        for f in frames.drain(..) {
            let Some(data) = &f.data else { continue };
            if data.get("job").as_f64().map(|n| n as u64) != Some(id) {
                continue;
            }
            match data.get("type").as_str() {
                Some("epoch") => {
                    // firehose frames are live bus events: each carries
                    // its sequence number as the SSE id
                    assert!(f.id.is_some(), "firehose frames must carry seqs");
                    epochs.push(data.get("stats").get("epoch").as_usize().unwrap());
                }
                Some("state") if data.get("state").as_str() == Some("done") => done = true,
                _ => {}
            }
        }
        if done {
            break;
        }
        assert!(t0.elapsed() < LONG, "never saw the terminal state on the firehose");
        let n = stream.read(&mut tmp).unwrap();
        assert!(n > 0, "stream closed before the terminal state");
        frames = parser.push(&tmp[..n]);
    }
    assert_eq!(epochs, vec![0, 1], "the replayed ring covers the whole finished run");
    drop(stream);
    shutdown(&addr, h);
}

#[test]
fn history_since_trims_polled_bodies() {
    let (addr, h) = start_server(1);
    let id = submit(&addr, &quick_job(3));
    poll_until(&addr, id, |v| v.get("state").as_str() == Some("done"), "done");

    let (status, full) = request(&addr, "GET", &format!("/jobs/{id}"), None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(full.get("history").as_arr().unwrap().len(), 3);
    assert_eq!(full.get("history_total").as_usize(), Some(3));

    let (status, tail) =
        request(&addr, "GET", &format!("/jobs/{id}?history_since=2"), None).unwrap();
    assert_eq!(status, 200);
    let hist = tail.get("history").as_arr().unwrap();
    assert_eq!(hist.len(), 1, "only epochs >= 2 ship");
    assert_eq!(hist[0].get("epoch").as_usize(), Some(2));
    assert_eq!(tail.get("history_total").as_usize(), Some(3), "total stays honest");

    // past the end: empty history, not an error
    let (status, none) =
        request(&addr, "GET", &format!("/jobs/{id}?history_since=99"), None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(none.get("history").as_arr().unwrap().len(), 0);

    let (status, v) =
        request(&addr, "GET", &format!("/jobs/{id}?history_since=x"), None).unwrap();
    assert_eq!(status, 400);
    assert!(v.get("error").as_str().unwrap().contains("history_since"));
    shutdown(&addr, h);
}

#[test]
fn watching_an_already_finished_job_replays_and_exits_cleanly() {
    let (addr, h) = start_server(1);
    let id = submit(&addr, &quick_job(2));
    poll_until(&addr, id, |v| v.get("state").as_str() == Some("done"), "done");

    // everything arrives as replay, the terminal snapshot state closes
    // the stream immediately — `repro watch` on a finished job exits 0
    let mut frames: Vec<WatchFrame> = Vec::new();
    let state = watch_job(&addr, id, |f| frames.push(f.clone())).unwrap();
    assert_eq!(state.as_str(), "done");
    assert_eq!(collect_epochs(&frames), vec![0, 1]);
    assert!(frames.iter().all(|f| match f {
        WatchFrame::Epoch { replay, .. } | WatchFrame::State { replay, .. } => *replay,
        WatchFrame::Lagged { .. } => false,
    }));

    // watching a job that never existed is a clean error (404 body)
    let err = watch_job(&addr, 999, |_| {}).unwrap_err();
    assert!(err.to_string().contains("404"), "{err:#}");
    shutdown(&addr, h);
}
