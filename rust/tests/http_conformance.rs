//! Keep-alive protocol conformance for the reactor connection plane
//! (`serve::reactor`): connection reuse, `Connection: close` honored
//! in both directions, pipelining answered in order, torn/oversized
//! headers behaving exactly like the old blocking scanner, malformed
//! `Content-Length` rejected, idle connections reaped without
//! touching live ones — and the shutdown-drain regression: a stalled
//! SSE client must not hold `/shutdown` open past the configured
//! grace.

use elasticzo::serve::{request, ServeOptions, Server};
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn boot(opts: ServeOptions) -> (String, std::thread::JoinHandle<()>) {
    let server = Server::bind(&opts).expect("bind");
    let addr = server.local_addr().expect("addr").to_string();
    let h = std::thread::spawn(move || server.run().expect("server run"));
    (addr, h)
}

fn opts() -> ServeOptions {
    ServeOptions { port: 0, workers: 1, queue_cap: 8, ..Default::default() }
}

fn find(h: &[u8], n: &[u8]) -> Option<usize> {
    h.windows(n.len()).position(|w| w == n)
}

/// Read exactly one content-length-framed response off the socket,
/// leaving any pipelined successor bytes in `buf`.
fn read_response(s: &mut TcpStream, buf: &mut Vec<u8>) -> (u16, String, String) {
    let mut tmp = [0u8; 4096];
    loop {
        if let Some(he) = find(buf, b"\r\n\r\n") {
            let head = String::from_utf8(buf[..he].to_vec()).expect("utf8 head");
            let clen: usize = head
                .lines()
                .find_map(|l| {
                    let (k, v) = l.split_once(':')?;
                    k.trim().eq_ignore_ascii_case("content-length").then(|| v.trim().parse().ok())?
                })
                .unwrap_or(0);
            if buf.len() >= he + 4 + clen {
                let body = String::from_utf8(buf[he + 4..he + 4 + clen].to_vec()).expect("body");
                buf.drain(..he + 4 + clen);
                let status: u16 =
                    head.split_whitespace().nth(1).expect("status").parse().expect("numeric");
                return (status, head, body);
            }
        }
        let n = s.read(&mut tmp).expect("read response");
        assert!(n > 0, "connection closed mid-response");
        buf.extend_from_slice(&tmp[..n]);
    }
}

fn connect(addr: &str) -> TcpStream {
    let s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).expect("timeout");
    s
}

#[test]
fn keepalive_reuses_one_socket_and_close_is_honored_both_ways() {
    let (addr, h) = boot(opts());
    let mut s = connect(&addr);
    let mut buf = Vec::new();

    // two requests, one socket: HTTP/1.1 defaults to keep-alive
    for _ in 0..2 {
        s.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        let (status, head, body) = read_response(&mut s, &mut buf);
        assert_eq!(status, 200);
        assert!(head.contains("Connection: keep-alive"), "server advertises reuse: {head}");
        assert!(body.contains("\"ok\":true"));
    }

    // the reuse is observable in the metrics (raw socket: /metrics is
    // the one non-JSON route, so the JSON client can't scrape it)
    let mut m = connect(&addr);
    m.write_all(b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
    let mut raw = Vec::new();
    m.read_to_end(&mut raw).expect("scrape");
    let text = String::from_utf8_lossy(&raw).to_string();
    let reuse_line = text
        .lines()
        .find(|l| l.starts_with("repro_http_keepalive_reuse_total"))
        .expect("keep-alive reuse counter exported");
    let reused: f64 = reuse_line.split_whitespace().last().unwrap().parse().unwrap();
    assert!(reused >= 1.0, "at least our second request reused: {reuse_line}");

    // client sends close -> server answers close and hangs up
    s.write_all(b"GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
    let (status, head, _) = read_response(&mut s, &mut buf);
    assert_eq!(status, 200);
    assert!(head.contains("Connection: close"), "close echoed: {head}");
    let mut tail = Vec::new();
    s.read_to_end(&mut tail).expect("clean EOF after close");
    assert!(tail.is_empty(), "no bytes after a closed exchange");

    // server sends close on its terminal response too: /shutdown
    let mut s = connect(&addr);
    let mut buf = Vec::new();
    s.write_all(b"POST /shutdown HTTP/1.1\r\n\r\n").unwrap();
    let (status, head, _) = read_response(&mut s, &mut buf);
    assert_eq!(status, 200);
    assert!(head.contains("Connection: close"), "shutdown never keeps alive: {head}");
    h.join().unwrap();
}

#[test]
fn pipelined_requests_are_answered_in_order() {
    let (addr, h) = boot(opts());
    let mut s = connect(&addr);
    let mut buf = Vec::new();

    // three requests in one write; responses must come back in order
    s.write_all(
        b"GET /healthz HTTP/1.1\r\n\r\nGET /stats HTTP/1.1\r\n\r\nGET /no-such-route HTTP/1.1\r\n\r\n",
    )
    .unwrap();
    let (status, _, body) = read_response(&mut s, &mut buf);
    assert_eq!(status, 200);
    assert!(body.contains("\"ok\":true"), "first answer is healthz: {body}");
    let (status, _, body) = read_response(&mut s, &mut buf);
    assert_eq!(status, 200);
    assert!(body.contains("jobs_total"), "second answer is stats: {body}");
    let (status, _, body) = read_response(&mut s, &mut buf);
    assert_eq!(status, 404);
    assert!(body.contains("no route"), "third answer is the 404: {body}");

    request(&addr, "POST", "/shutdown", None).unwrap();
    h.join().unwrap();
}

#[test]
fn torn_and_split_headers_parse_like_the_blocking_scanner() {
    let (addr, h) = boot(opts());

    // tear a request (with body) into single bytes across many TCP
    // segments; the resumable scanner must reassemble it
    let mut s = connect(&addr);
    let mut buf = Vec::new();
    let wire = b"POST /jobs HTTP/1.1\r\nContent-Type: application/json\r\nContent-Length: 2\r\n\r\n{}";
    for chunk in wire.chunks(1) {
        s.write_all(chunk).unwrap();
        s.flush().unwrap();
    }
    let (status, _, body) = read_response(&mut s, &mut buf);
    assert_eq!(status, 400, "{{}} is valid JSON but not a job spec: {body}");
    assert!(body.contains("invalid job spec"), "reached the router, not the parser: {body}");

    // split exactly across the \r\n\r\n terminator
    let mut s = connect(&addr);
    let mut buf = Vec::new();
    s.write_all(b"GET /healthz HTTP/1.1\r\n").unwrap();
    std::thread::sleep(Duration::from_millis(50));
    s.write_all(b"\r").unwrap();
    std::thread::sleep(Duration::from_millis(50));
    s.write_all(b"\n").unwrap();
    let (status, _, _) = read_response(&mut s, &mut buf);
    assert_eq!(status, 200);

    request(&addr, "POST", "/shutdown", None).unwrap();
    h.join().unwrap();
}

#[test]
fn oversized_headers_and_bad_content_length_get_400() {
    let (addr, h) = boot(opts());

    // malformed Content-Length
    let mut s = connect(&addr);
    let mut buf = Vec::new();
    s.write_all(b"POST /jobs HTTP/1.1\r\nContent-Length: banana\r\n\r\n").unwrap();
    let (status, _, body) = read_response(&mut s, &mut buf);
    assert_eq!(status, 400);
    assert!(body.contains("bad content-length"), "{body}");

    // oversized headers: the server 400s mid-upload, so later writes
    // may fail with a reset — only the response matters
    let mut s = connect(&addr);
    let mut buf = Vec::new();
    s.write_all(b"GET /healthz HTTP/1.1\r\nX-Pad: ").unwrap();
    let pad = vec![b'x'; 8 * 1024];
    for _ in 0..10 {
        if s.write_all(&pad).is_err() {
            break;
        }
    }
    let (status, _, body) = read_response(&mut s, &mut buf);
    assert_eq!(status, 400);
    assert!(body.contains("headers too large"), "{body}");

    request(&addr, "POST", "/shutdown", None).unwrap();
    h.join().unwrap();
}

#[test]
fn idle_keepalive_connections_are_reaped_without_affecting_healthz() {
    let (addr, h) = boot(ServeOptions { http_idle: Duration::from_millis(300), ..opts() });

    let mut idle = connect(&addr);
    let mut buf = Vec::new();
    idle.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
    let (status, _, _) = read_response(&mut idle, &mut buf);
    assert_eq!(status, 200);

    // park the connection past the idle timeout; the reactor reaps it
    std::thread::sleep(Duration::from_millis(1200));
    let mut tmp = [0u8; 64];
    match idle.read(&mut tmp) {
        Ok(0) => {} // clean server-side close
        Ok(n) => panic!("unexpected {n} bytes on a reaped connection"),
        // a reset is also an acceptable spelling of "reaped"
        Err(e) => assert!(
            matches!(e.kind(), ErrorKind::ConnectionReset | ErrorKind::ConnectionAborted),
            "unexpected error: {e}"
        ),
    }

    // reaping idle sockets never touches fresh traffic
    let (status, v) = request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(v.get("ok").as_bool(), Some(true));

    request(&addr, "POST", "/shutdown", None).unwrap();
    h.join().unwrap();
}

#[test]
fn stalled_sse_client_cannot_delay_shutdown_drain_past_grace() {
    let (addr, h) = boot(ServeOptions {
        drain_grace: Duration::from_millis(500),
        events_buffer: 4,
        ..opts()
    });

    // an SSE subscriber that never reads a single byte — under the old
    // blocking writer this could hold the drain open for the write
    // timeout; the reactor must cut it loose at drain_grace
    let mut stalled = connect(&addr);
    stalled.write_all(b"GET /events HTTP/1.1\r\n\r\n").unwrap();
    // give the reactor a moment to install the stream
    std::thread::sleep(Duration::from_millis(200));

    let t0 = Instant::now();
    let (status, _) = request(&addr, "POST", "/shutdown", None).unwrap();
    assert_eq!(status, 200);
    h.join().unwrap();
    let drain = t0.elapsed();
    assert!(
        drain < Duration::from_secs(3),
        "drain took {drain:?} with a stalled SSE client (grace was 500ms)"
    );
    drop(stalled);
}
