//! End-to-end training integration tests: the full coordinator session
//! loop over both precisions, at smoke scale — the paper's headline
//! behaviours as assertions.

use elasticzo::config::Config;
use elasticzo::coordinator::{
    checkpoint, int8_trainer, trainer, Method, Model, ParamSet, PrecisionSpec, TrainSpec,
    ZoGradMode,
};
use elasticzo::coordinator::native_engine::NativeEngine;
use elasticzo::data::{self, DatasetKind};
use elasticzo::int8::lenet8;
use elasticzo::util::cli::Args;

/// Debug builds (plain `cargo test`) run the native engine ~20x slower
/// than release; shrink the workloads there so the suite stays fast.
fn scaled(n: usize) -> usize {
    if cfg!(debug_assertions) {
        (n / 2).max(2)
    } else {
        n
    }
}

/// Accuracy thresholds are halved in debug builds (fewer samples/epochs).
fn thr(x: f32) -> f32 {
    if cfg!(debug_assertions) {
        x * 0.5
    } else {
        x
    }
}

fn spec(method: Method, epochs: usize) -> TrainSpec {
    TrainSpec {
        method,
        epochs,
        batch: 16,
        lr0: if method == Method::FullBp { 0.05 } else { 2e-3 },
        eps: 1e-2,
        g_clip: 5.0,
        seed: 3,
        eval_every: 1,
        verbose: false,
        ..Default::default()
    }
}

fn int8_spec(method: Method, grad_mode: ZoGradMode, epochs: usize) -> TrainSpec {
    TrainSpec {
        method,
        precision: PrecisionSpec::int8(grad_mode),
        seed: 11,
        ..spec(method, epochs)
    }
}

#[test]
fn elastic_beats_full_zo_at_equal_budget() {
    // the paper's core claim, at smoke scale, native engine
    let (train_d, test_d) = data::generate(DatasetKind::SynthMnist, scaled(512), scaled(256), 5, 0);
    let mut acc = std::collections::HashMap::new();
    for method in [Method::FULL_ZO, Method::CLS1] {
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 6);
        let r = trainer::train(&mut eng, &mut params, &train_d, &test_d, &spec(method, scaled(6)))
            .unwrap();
        acc.insert(method.label(), r.history.best_test_acc());
    }
    assert!(
        acc["ZO-Feat-Cls1"] > acc["Full ZO"],
        "Cls1 {} must beat FullZO {}",
        acc["ZO-Feat-Cls1"],
        acc["Full ZO"]
    );
}

#[test]
fn full_bp_reaches_high_accuracy() {
    let (train_d, test_d) = data::generate(DatasetKind::SynthMnist, scaled(768), scaled(256), 7, 0);
    let mut eng = NativeEngine::new(Model::LeNet);
    let mut params = ParamSet::init(Model::LeNet, 8);
    let r = trainer::train(&mut eng, &mut params, &train_d, &test_d, &spec(Method::FullBp, scaled(5)))
        .unwrap();
    assert!(r.history.best_test_acc() > thr(0.7), "{}", r.history.best_test_acc());
    // regression (full_step logits ABI): Full BP reports train accuracy
    let last = r.history.epochs.last().unwrap();
    assert!(last.train_acc > 0.0, "Full BP train_acc must be live");
}

#[test]
fn int8_elastic_trains_with_integer_only_gradient() {
    // INT8* end to end: no float in the ZO gradient path
    let (train_d, test_d) = data::generate(DatasetKind::SynthMnist, scaled(512), scaled(256), 9, 0);
    let mut ws = lenet8::init_params(10, 32);
    let r = int8_trainer::train_int8(
        &mut ws,
        &train_d,
        &test_d,
        &int8_spec(Method::CLS1, ZoGradMode::IntCE, scaled(5)),
    )
    .unwrap();
    // well above chance (10%)
    assert!(r.history.best_test_acc() > thr(0.25), "{}", r.history.best_test_acc());
}

#[test]
fn finetuning_recovers_rotation_shift() {
    // Table-2 protocol at smoke scale
    let (train_d, test_d) = data::generate(DatasetKind::SynthMnist, scaled(768), scaled(384), 13, 0);
    let mut eng = NativeEngine::new(Model::LeNet);
    let mut params = ParamSet::init(Model::LeNet, 14);
    trainer::train(&mut eng, &mut params, &train_d, &test_d, &spec(Method::FullBp, scaled(5))).unwrap();

    let rot_train = data::rotate::rotate_dataset(&train_d.split_at(scaled(512)).0, 45.0);
    let rot_test = data::rotate::rotate_dataset(&test_d, 45.0);
    let (_, acc_before) = trainer::evaluate(&mut eng, &params, &rot_test, 16).unwrap();

    let r = trainer::train(&mut eng, &mut params, &rot_train, &rot_test, &spec(Method::CLS1, scaled(6)))
        .unwrap();
    let acc_after = r.history.best_test_acc();
    assert!(
        acc_after > acc_before + thr(0.05),
        "fine-tuning must recover: {acc_before} -> {acc_after}"
    );
}

#[test]
fn deterministic_replay_same_seed() {
    // identical spec + seed => identical run, down to the bit pattern
    // of every reported metric AND the final parameters (seed trick +
    // data pipeline are fully deterministic; a plain float == would
    // let ±0.0 or latent NaNs slip through)
    let (train_d, test_d) = data::generate(DatasetKind::SynthMnist, 256, 128, 15, 0);
    let run = || {
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 16);
        let h = trainer::train(&mut eng, &mut params, &train_d, &test_d, &spec(Method::CLS2, 2))
            .unwrap()
            .history;
        (h, params)
    };
    let (h1, p1) = run();
    let (h2, p2) = run();
    assert_eq!(h1.epochs.len(), h2.epochs.len());
    for (a, b) in h1.epochs.iter().zip(&h2.epochs) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits());
        assert_eq!(a.train_acc.to_bits(), b.train_acc.to_bits());
        assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits());
        assert_eq!(a.lr.to_bits(), b.lr.to_bits());
        assert!(a.train_loss.is_finite(), "epoch {} loss {}", a.epoch, a.train_loss);
    }
    for (i, (x, y)) in p1.data.iter().zip(&p2.data).enumerate() {
        let xb: Vec<u32> = x.iter().map(|v| v.to_bits()).collect();
        let yb: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
        assert_eq!(xb, yb, "tensor {i}");
    }
}

#[test]
fn boundary_sweep_legacy_tokens_match_bp_tail_spellings() {
    // `Method::Tail(k)` generalizes the paper's presets; every legacy
    // token must stay a bitwise-equivalent ALIAS of its `bp-tail=<k>`
    // spelling — same per-epoch metrics bit patterns, same final
    // parameters — through the full CLI → Config → trainer pipeline
    let (train_d, test_d) = data::generate(DatasetKind::SynthMnist, 192, 96, 21, 0);
    let run = |token: &str| {
        let args = Args::parse(
            ["--method", token, "--engine", "native"].iter().map(|s| s.to_string()),
        );
        let cfg = Config::from_args(&args).unwrap();
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 22);
        let h = trainer::train(&mut eng, &mut params, &train_d, &test_d, &spec(cfg.method, 2))
            .unwrap()
            .history;
        (h, params)
    };
    for (legacy, tail) in [("full-zo", "bp-tail=0"), ("cls2", "bp-tail=1"), ("cls1", "bp-tail=2")]
    {
        let (h1, p1) = run(legacy);
        let (h2, p2) = run(tail);
        assert_eq!(h1.epochs.len(), h2.epochs.len());
        for (a, b) in h1.epochs.iter().zip(&h2.epochs) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{legacy} vs {tail}");
            assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits(), "{legacy} vs {tail}");
            assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits(), "{legacy} vs {tail}");
        }
        for (i, (x, y)) in p1.data.iter().zip(&p2.data).enumerate() {
            let xb: Vec<u32> = x.iter().map(|v| v.to_bits()).collect();
            let yb: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
            assert_eq!(xb, yb, "{legacy} vs {tail} tensor {i}");
        }
        // the preset serializes back to its legacy token byte-for-byte
        // (checkpoint spec identity + wire compatibility)
        assert_eq!(Method::parse(tail).unwrap().token(), legacy);
    }
    // full-bp has no tail spelling, and bp-tail=3 is a genuinely new
    // point on the k-axis, not an alias of any preset
    assert_eq!(Method::parse("full-bp").unwrap(), Method::FullBp);
    assert_eq!(Method::parse("bp-tail=3").unwrap().token(), "bp-tail=3");
}

#[test]
fn checkpoint_resume_matches_continuous_eval() {
    let (train_d, test_d) = data::generate(DatasetKind::SynthMnist, 256, 128, 17, 0);
    let mut eng = NativeEngine::new(Model::LeNet);
    let mut params = ParamSet::init(Model::LeNet, 18);
    trainer::train(&mut eng, &mut params, &train_d, &test_d, &spec(Method::FullBp, 2)).unwrap();
    let path = std::env::temp_dir().join(format!("ezo_e2e_{}.ckpt", std::process::id()));
    checkpoint::save_params(&path, &params).unwrap();
    let mut params2 = ParamSet::init(Model::LeNet, 999);
    checkpoint::load_params(&path, &mut params2).unwrap();
    let (l1, a1) = trainer::evaluate(&mut eng, &params, &test_d, 16).unwrap();
    let (l2, a2) = trainer::evaluate(&mut eng, &params2, &test_d, 16).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(a1, a2);
    std::fs::remove_file(path).ok();
}

#[test]
fn config_cli_pipeline() {
    let args = Args::parse(
        ["--method", "cls2", "--precision", "int8*", "--epochs", "2", "--batch", "8"]
            .iter()
            .map(|s| s.to_string()),
    );
    let cfg = Config::from_args(&args).unwrap();
    assert_eq!(cfg.method, Method::CLS2);
    assert_eq!(cfg.precision.grad_mode(), ZoGradMode::IntCE);
    assert_eq!(cfg.batch, 8);
    // the CLI pipeline lands on the same unified spec the sessions take
    let s = cfg.train_spec();
    assert_eq!(s.precision, PrecisionSpec::Int8 { grad_mode: ZoGradMode::IntCE, r_max: 15, b_zo: 1 });
    assert_eq!(s.label(), "ZO-Feat-Cls2 INT8*");
    // the kernel path is the default and dense perturbation its default shape
    assert!(s.kernels, "kernels must default on through the CLI pipeline");
    assert_eq!(s.sparse_block, 0);
}

#[test]
fn pointnet_native_training_improves() {
    let model = Model::PointNet { npoints: 32, ncls: 40 };
    let (train_d, test_d) = data::generate(DatasetKind::SynthModelNet, scaled(640), scaled(160), 19, 32);
    let mut eng = NativeEngine::new(model);
    let mut params = ParamSet::init(model, 20);
    // full BP verifies the whole native PointNet fwd/bwd path learns;
    // 40-way at this tiny scale needs the strongest learner (the
    // ElasticZO-vs-FullZO ordering is checked at exp scale instead)
    let mut s = spec(Method::FullBp, scaled(8));
    s.batch = 16;
    let r = trainer::train(&mut eng, &mut params, &train_d, &test_d, &s).unwrap();
    // 40-way chance is 2.5%
    assert!(r.history.best_test_acc() > thr(0.12), "{}", r.history.best_test_acc());
}
