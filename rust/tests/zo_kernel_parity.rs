//! Kernel parity & property suite: pins the chunked/parallel ZO
//! kernels (`coordinator::kernels`) to the scalar reference path,
//! bit for bit. Covers the micro level (Gaussian fill, perturb legs,
//! int8 update) and the macro level (whole training runs with
//! `spec.kernels` on vs off — fp32, int8, dp N=2). The structured
//! perturbation flag is the one intentional divergence and is tested
//! as exactly that: different trajectory, still deterministic.

use elasticzo::coordinator::int8_trainer::{self, perturb_int8, zo_update_int8};
use elasticzo::coordinator::metrics::History;
use elasticzo::coordinator::native_engine::NativeEngine;
use elasticzo::coordinator::{
    kernels, session, trainer, zo, DpAggregate, DpLocalSession, DpSpec, DpWorld, Method, Model,
    ParamSet, PrecisionSpec, TrainSpec, ZoGradMode,
};
use elasticzo::data::{self, DatasetKind};
use elasticzo::int8::lenet8;
use elasticzo::rng::ZoStream;
use std::sync::Once;

/// The container running `cargo test` may expose a single core, which
/// would silently reduce every parallel branch to its sequential
/// fallback. Force a 4-thread kernel pool (the override is read once,
/// before any test touches the kernels) so the scoped-thread paths —
/// chunked Gaussian fill, the ±ε pair, dp shard fan-out — actually
/// run multi-threaded while the suite checks their bits.
fn force_threads() {
    static INIT: Once = Once::new();
    INIT.call_once(|| std::env::set_var("REPRO_KERNEL_THREADS", "4"));
}

#[test]
fn thread_override_is_respected() {
    force_threads();
    assert_eq!(kernels::hw_threads(), 4);
}

#[test]
fn fill_z_matches_sequential_stream_bitwise() {
    force_threads();
    // sizes straddle the per-thread chunking threshold: 100k elements
    // is 50k pairs, enough for 3 worker threads
    for n in [0usize, 1, 2, 255, 4096, 100_000] {
        let mut out = vec![0.0f32; n];
        kernels::fill_z(21, 9, &mut out);
        let mut s = ZoStream::for_step(21, 9);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v.to_bits(), s.normal().to_bits(), "n={n} elem {i}");
        }
    }
}

#[test]
fn fp32_perturb_legs_match_scalar_bitwise() {
    force_threads();
    // the exact leg sequence of a ZO step (+ε, −2ε, +ε restore, then
    // the −lr·g commit), kernel vs scalar, on both model sizes
    for (model, k) in [(Model::LeNet, 1usize), (Model::PointNet { npoints: 32, ncls: 40 }, 2)] {
        let mut scalar = ParamSet::init(model, 13);
        let mut kernel = scalar.clone();
        let boundary = scalar.zo_boundary(k);
        let n: usize = kernel.data[..boundary].iter().map(|t| t.len()).sum();
        let mut kz = kernels::StepZ::new();
        for (step, scale) in [(4u64, 1e-2f32), (4, -2e-2), (4, 1e-2), (4, -3.7e-4), (5, 1e-2)] {
            zo::perturb(&mut scalar, boundary, 17, step, scale);
            kz.prepare(17, step, n, None);
            kernels::apply_z(&mut kernel, boundary, scale, kz.z());
        }
        for (i, (a, b)) in scalar.data.iter().zip(&kernel.data).enumerate() {
            let a: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "{model:?} tensor {i}");
        }
    }
}

#[test]
fn int8_legs_and_update_match_scalar() {
    force_threads();
    let (n_zo, seed, r_max, p_zero) = (4usize, 23u64, 15i8, 0.5f32);
    let mut scalar = lenet8::init_params(7, 32);
    let mut kernel = scalar.clone();
    let n: usize = kernel[..n_zo].iter().map(|w| w.numel()).sum();
    let mut kz = kernels::StepZi8::new();
    let (mut acc, mut upd) = (Vec::new(), Vec::new());
    for step in 1u64..=3 {
        kz.prepare(seed, step, n, r_max, p_zero);
        for k in [1i32, -2, 1] {
            perturb_int8(&mut scalar, n_zo, seed, step, k, r_max, p_zero);
            kernels::apply_z_i8(&mut kernel, n_zo, k, kz.z());
            assert_eq!(scalar, kernel, "step {step} leg k={k}");
        }
        // g spans the sign cases the integer CE can emit, including the
        // g=0 no-op
        let g = [(-1i32), 0, 1][(step % 3) as usize];
        zo_update_int8(&mut scalar, n_zo, seed, step, g, 1, r_max, p_zero);
        kernels::zo_update_z_i8(&mut kernel, n_zo, g, 1, kz.z(), &mut acc, &mut upd);
        assert_eq!(scalar, kernel, "step {step} update g={g}");
    }
}

fn fp32_spec(method: Method, kernels_on: bool) -> TrainSpec {
    TrainSpec {
        method,
        epochs: 2,
        batch: 16,
        lr0: 2e-3,
        eps: 1e-2,
        g_clip: 5.0,
        seed: 3,
        eval_every: 1,
        verbose: false,
        kernels: kernels_on,
        ..Default::default()
    }
}

/// Epoch histories must agree bit for bit on every trained quantity;
/// `seconds`/`phases` are wall-clock attribution and are the only
/// fields allowed to differ between the kernel and scalar paths.
fn assert_history_bits_eq(a: &History, b: &History) {
    assert_eq!(a.epochs.len(), b.epochs.len());
    for (x, y) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(x.epoch, y.epoch);
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.train_acc.to_bits(), y.train_acc.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.lr.to_bits(), y.lr.to_bits(), "epoch {}", x.epoch);
    }
}

fn assert_params_bits_eq(a: &ParamSet, b: &ParamSet) {
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        let x: Vec<u32> = x.iter().map(|v| v.to_bits()).collect();
        let y: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
        assert_eq!(x, y, "tensor {i}");
    }
}

#[test]
fn fp32_e2e_trajectory_identical_kernels_on_off() {
    force_threads();
    let (train_d, test_d) = data::generate(DatasetKind::SynthMnist, 96, 48, 5, 0);
    for method in [Method::FULL_ZO, Method::CLS1] {
        let run = |kernels_on: bool| {
            let mut eng = NativeEngine::new(Model::LeNet);
            let mut params = ParamSet::init(Model::LeNet, 6);
            let r = trainer::train(
                &mut eng,
                &mut params,
                &train_d,
                &test_d,
                &fp32_spec(method, kernels_on),
            )
            .unwrap();
            (r.history, params)
        };
        let (h_on, p_on) = run(true);
        let (h_off, p_off) = run(false);
        assert_history_bits_eq(&h_on, &h_off);
        assert_params_bits_eq(&p_on, &p_off);
    }
}

#[test]
fn int8_e2e_trajectory_identical_kernels_on_off() {
    force_threads();
    let (train_d, test_d) = data::generate(DatasetKind::SynthMnist, 96, 48, 7, 0);
    for grad_mode in [ZoGradMode::IntCE, ZoGradMode::FloatCE] {
        let run = |kernels_on: bool| {
            let spec = TrainSpec {
                precision: PrecisionSpec::int8(grad_mode),
                seed: 11,
                ..fp32_spec(Method::CLS1, kernels_on)
            };
            let mut ws = lenet8::init_params(10, 32);
            let r = int8_trainer::train_int8(&mut ws, &train_d, &test_d, &spec).unwrap();
            (r.history, ws)
        };
        let (h_on, w_on) = run(true);
        let (h_off, w_off) = run(false);
        assert_history_bits_eq(&h_on, &h_off);
        assert_eq!(w_on, w_off, "{grad_mode:?} final int8 weights");
    }
}

#[test]
fn dp_n2_trajectory_identical_kernels_on_off() {
    force_threads();
    let (train_d, test_d) = data::generate(DatasetKind::SynthMnist, 96, 48, 9, 0);
    let run = |kernels_on: bool| {
        let spec = fp32_spec(Method::FULL_ZO, kernels_on);
        let dp = DpSpec { replicas: 2, aggregate: DpAggregate::Mean, min_replicas: 1 };
        let world = DpWorld::new(Model::LeNet, spec.clone(), dp, train_d.len()).unwrap();
        let mut sess = DpLocalSession::new(world);
        let r = session::run(&mut sess, &spec, &train_d, &test_d).unwrap();
        (r.history, sess.world.params)
    };
    let (h_on, p_on) = run(true);
    let (h_off, p_off) = run(false);
    assert_history_bits_eq(&h_on, &h_off);
    assert_params_bits_eq(&p_on, &p_off);
}

#[test]
fn sparse_perturbation_diverges_but_stays_deterministic() {
    force_threads();
    let (train_d, test_d) = data::generate(DatasetKind::SynthMnist, 96, 48, 13, 0);
    let run = |block: usize| {
        let spec = TrainSpec {
            sparse_block: block,
            sparse_keep: if block > 0 { 0.5 } else { 1.0 },
            ..fp32_spec(Method::CLS1, true)
        };
        let mut eng = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 6);
        let r = trainer::train(&mut eng, &mut params, &train_d, &test_d, &spec).unwrap();
        (r.history, params)
    };
    // deterministic: same sparse spec twice => identical trajectory
    let (h1, p1) = run(64);
    let (h2, p2) = run(64);
    assert_history_bits_eq(&h1, &h2);
    assert_params_bits_eq(&p1, &p2);
    // intentionally divergent: masking blocks of z changes the
    // trajectory relative to the dense path
    let (_, dense) = run(0);
    let differs = p1
        .data
        .iter()
        .zip(&dense.data)
        .any(|(a, b)| a.iter().zip(b).any(|(x, y)| x.to_bits() != y.to_bits()));
    assert!(differs, "sparse_block=64 keep=0.5 must change the trajectory");
}
