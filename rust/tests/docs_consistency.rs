//! The API doc drifted from the server twice in four PRs; this test
//! makes that impossible to repeat silently. It extracts every route
//! pattern from `serve/http.rs`'s dispatch matches (`("GET",
//! ["jobs", id])` → `GET /jobs/{}`) and every route row from the
//! tables in `docs/SERVE_API.md` (`` `GET  /jobs/{id}` `` → `GET
//! /jobs/{}`), and requires the two sets to be identical — a route
//! added to the server without a doc row fails, and so does a
//! documented route the server no longer dispatches.

use std::collections::BTreeSet;

const HTTP_RS: &str = include_str!("../src/serve/http.rs");
const SERVE_API_MD: &str = include_str!("../docs/SERVE_API.md");

/// Routes dispatched by `serve/http.rs`: every `("METHOD", [segs…])`
/// slice pattern in the routing code (the `#[cfg(test)]` module is
/// excluded). Bound identifiers and `_` become the `{}` placeholder;
/// arms inside `route_cluster` get the `/cluster` prefix; the
/// `rest @ ..` delegation arm is skipped (it is not a route).
fn source_routes(src: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let mut prefix = "";
    for line in src.lines() {
        if line.trim_start().starts_with("#[cfg(test)]") {
            break; // unit tests mention paths, not routes
        }
        // each fn boundary resets the prefix; only route_cluster's
        // arms live under /cluster
        if line.contains("fn ") {
            prefix = if line.contains("fn route_cluster") { "/cluster" } else { "" };
        }
        for method in ["GET", "POST"] {
            let pat = format!("(\"{method}\", [");
            let mut from = 0;
            while let Some(ix) = line[from..].find(&pat) {
                let start = from + ix + pat.len();
                let Some(len) = line[start..].find(']') else { break };
                let inner = &line[start..start + len];
                let mut segs: Vec<String> = Vec::new();
                let mut delegation = false;
                for part in inner.split(',') {
                    let part = part.trim();
                    if part.is_empty() {
                        continue;
                    }
                    if part.contains("..") {
                        delegation = true; // `rest @ ..`: a sub-router, not a route
                        break;
                    }
                    match part.strip_prefix('"').and_then(|p| p.strip_suffix('"')) {
                        Some(lit) => segs.push(lit.to_string()),
                        None => segs.push("{}".to_string()),
                    }
                }
                if !delegation {
                    out.insert(format!("{method} {prefix}/{}", segs.join("/")));
                }
                from = start + len;
            }
        }
    }
    out
}

/// Routes documented in `SERVE_API.md`: the first backticked cell of
/// every table row that parses as `METHOD /path`. `{id}`-style path
/// parameters normalize to `{}`.
fn doc_routes(doc: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in doc.lines() {
        let t = line.trim_start();
        if !t.starts_with('|') {
            continue;
        }
        let Some(a) = t.find('`') else { continue };
        let rest = &t[a + 1..];
        let Some(b) = rest.find('`') else { continue };
        let cell = &rest[..b];
        let mut it = cell.split_whitespace();
        let (Some(method), Some(path), None) = (it.next(), it.next(), it.next()) else {
            continue;
        };
        if !(method == "GET" || method == "POST") || !path.starts_with('/') {
            continue;
        }
        let segs: Vec<String> = path
            .split('/')
            .filter(|s| !s.is_empty())
            .map(|s| if s.starts_with('{') { "{}".to_string() } else { s.to_string() })
            .collect();
        out.insert(format!("{method} /{}", segs.join("/")));
    }
    out
}

#[test]
fn every_dispatched_route_is_documented_and_vice_versa() {
    let in_src = source_routes(HTTP_RS);
    let in_doc = doc_routes(SERVE_API_MD);

    // guard against the extractors going blind and vacuously passing
    for expected in [
        "GET /healthz",
        "POST /jobs",
        "GET /jobs/{}",
        "GET /jobs/{}/events",
        "GET /events",
        "POST /cluster/register",
        "POST /cluster/agents/{}/jobs/{}/epoch",
    ] {
        assert!(in_src.contains(expected), "route extractor missed {expected}: {in_src:?}");
    }
    assert!(in_src.len() >= 15, "suspiciously few routes extracted: {in_src:?}");
    assert!(in_doc.len() >= 15, "suspiciously few doc rows extracted: {in_doc:?}");

    let undocumented: Vec<&String> = in_src.difference(&in_doc).collect();
    let phantom: Vec<&String> = in_doc.difference(&in_src).collect();
    assert!(
        undocumented.is_empty(),
        "routes dispatched in serve/http.rs but missing from docs/SERVE_API.md \
         (add a table row): {undocumented:?}"
    );
    assert!(
        phantom.is_empty(),
        "routes documented in docs/SERVE_API.md but not dispatched in serve/http.rs \
         (stale doc row?): {phantom:?}"
    );
}

#[test]
fn doc_table_parser_reads_the_expected_shape() {
    let rows = doc_routes(
        "| Method + path | Action |\n\
         |---|---|\n\
         | `GET  /jobs/{id}` | detail (`?history_since=`) |\n\
         | `POST /cluster/agents/{a}/poll` | heartbeat |\n\
         prose mentioning `GET /events` outside a table\n",
    );
    assert_eq!(
        rows.into_iter().collect::<Vec<_>>(),
        vec!["GET /jobs/{}".to_string(), "POST /cluster/agents/{}/poll".to_string()]
    );
}

#[test]
fn source_pattern_parser_reads_the_expected_shape() {
    let routes = source_routes(
        "fn route(&self) {\n\
             (\"GET\", [\"jobs\", id]) => x,\n\
             (m, [\"cluster\", rest @ ..]) => y,\n\
         }\n\
         fn route_cluster(&self) {\n\
             (\"POST\", [\"agents\", aid, \"poll\"]) => z,\n\
         }\n\
         fn other() { matches!(x, (\"GET\", [\"events\"]) | (\"GET\", [\"jobs\", _, \"events\"])) }\n\
         #[cfg(test)]\n\
         mod tests { (\"GET\", [\"not-a-route\"]) }\n",
    );
    let want: BTreeSet<String> = [
        "GET /jobs/{}",
        "POST /cluster/agents/{}/poll",
        "GET /events",
        "GET /jobs/{}/events",
    ]
    .into_iter()
    .map(String::from)
    .collect();
    assert_eq!(routes, want);
}
