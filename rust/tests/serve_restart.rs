//! Restart e2e for the persistent job journal: boot a journaled
//! server, run one job to Done and interrupt another mid-run via
//! shutdown, then boot a SECOND server on the same journal and check
//! that (a) the finished job is still listed with its terminal state
//! and history, and (b) the interrupted job was requeued and resumed
//! from its last checkpoint through to completion.

use elasticzo::serve::{request, ServeOptions, Server};
use elasticzo::util::json::Value;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

const LONG: Duration = Duration::from_secs(300);

fn start_server(journal: &str) -> (String, JoinHandle<()>) {
    let server = Server::bind(&ServeOptions {
        port: 0,
        workers: 1,
        queue_cap: 8,
        journal: Some(journal.to_string()),
        ..Default::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || server.run().unwrap());
    (addr, h)
}

fn shutdown(addr: &str, handle: JoinHandle<()>) {
    let (status, _) = request(addr, "POST", "/shutdown", None).unwrap();
    assert_eq!(status, 200);
    handle.join().unwrap();
}

fn submit(addr: &str, spec: &str) -> u64 {
    let body = elasticzo::util::json::parse(spec).unwrap();
    let (status, v) = request(addr, "POST", "/jobs", Some(&body)).unwrap();
    assert_eq!(status, 200, "submit failed: {}", elasticzo::util::json::to_string(&v));
    v.get("id").as_f64().unwrap() as u64
}

fn get_job(addr: &str, id: u64) -> Value {
    let (status, v) = request(addr, "GET", &format!("/jobs/{id}"), None).unwrap();
    assert_eq!(status, 200, "job {id} must exist");
    v
}

fn poll_until(addr: &str, id: u64, pred: impl Fn(&Value) -> bool, what: &str) -> Value {
    let t0 = Instant::now();
    loop {
        let v = get_job(addr, id);
        if pred(&v) {
            return v;
        }
        assert!(
            t0.elapsed() < LONG,
            "timed out waiting for {what} on job {id}; last: {}",
            elasticzo::util::json::to_string(&v)
        );
        std::thread::sleep(Duration::from_millis(30));
    }
}

#[test]
fn restart_replays_jobs_and_resumes_interrupted_runs() {
    let dir = std::env::temp_dir().join(format!("ezo_restart_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("journal.jsonl").display().to_string();
    let ckpt = dir.join("long.ckpt").display().to_string();
    std::fs::remove_file(&journal).ok();
    std::fs::remove_file(&ckpt).ok();

    // release-mode epochs are ~2 orders of magnitude faster; keep the
    // long job long enough that the shutdown below lands mid-run
    let epochs: usize = if cfg!(debug_assertions) { 20 } else { 200 };

    // ---- server A: one quick job to Done, one long job interrupted
    let (addr, h) = start_server(&journal);
    let quick = submit(
        &addr,
        r#"{"name": "quick", "method": "cls1", "precision": "fp32",
            "engine": "native", "epochs": 2, "batch": 16,
            "train_n": 192, "test_n": 96, "seed": 7}"#,
    );
    poll_until(&addr, quick, |v| v.get("state").as_str() == Some("done"), "quick job done");

    let long = submit(
        &addr,
        &format!(
            r#"{{"name": "long", "method": "full-zo", "precision": "fp32",
                "engine": "native", "epochs": {epochs}, "batch": 16,
                "train_n": 64, "test_n": 32, "seed": 5, "save": "{ckpt}"}}"#
        ),
    );
    // let it make real progress (and write cadence snapshots), then
    // shut the server down mid-run — the job must land as interrupted
    poll_until(
        &addr,
        long,
        |v| v.get("epochs_done").as_usize().unwrap_or(0) >= 2,
        "two epochs of the long job",
    );
    shutdown(&addr, h);

    // the compacted journal records the shutdown-stop as interrupted
    // (NOT cancelled: a user cancel would stay terminal on restart)
    let replayed = elasticzo::serve::journal::replay(&journal).unwrap();
    let rl = replayed.iter().find(|j| j.id == long).expect("long job journaled");
    assert_eq!(
        rl.state,
        elasticzo::serve::JobState::Interrupted,
        "shutdown must interrupt, not cancel"
    );
    assert!(rl.epochs.len() >= 2, "progress journaled: {}", rl.epochs.len());

    // ---- server B on the same journal
    let (addr, h) = start_server(&journal);

    // the finished job survived the restart with state + history intact
    let vq = get_job(&addr, quick);
    assert_eq!(vq.get("state").as_str(), Some("done"));
    assert_eq!(vq.get("name").as_str(), Some("quick"));
    assert_eq!(vq.get("history").as_arr().unwrap().len(), 2);
    assert!(vq.get("best_test_acc").as_f64().unwrap() > 0.0);

    // the interrupted job was requeued (resume armed) and runs through
    // to completion: all epochs present, no duplicates
    let vl = poll_until(
        &addr,
        long,
        |v| v.get("state").as_str() == Some("done"),
        "long job resumed to done",
    );
    assert_eq!(vl.get("epochs_done").as_usize(), Some(epochs));
    let history = vl.get("history").as_arr().unwrap();
    assert_eq!(history.len(), epochs, "replayed + resumed epochs must form one history");
    for (i, e) in history.iter().enumerate() {
        assert_eq!(e.get("epoch").as_usize(), Some(i), "history must be the epochs 0..{epochs}");
    }
    // the requeued spec carries the resume path back through the wire
    assert_eq!(vl.get("spec").get("resume").as_str(), Some(ckpt.as_str()));

    // the final checkpoint on disk covers the full run
    let (_, state) = elasticzo::coordinator::checkpoint::load_full(&ckpt).unwrap();
    assert_eq!(state.unwrap().epochs_done, epochs);

    // stats reflect the replayed table
    let (_, s) = request(&addr, "GET", "/stats", None).unwrap();
    assert_eq!(s.get("jobs_total").as_usize(), Some(2));
    assert_eq!(s.get("jobs_done").as_usize(), Some(2));

    shutdown(&addr, h);

    // ---- a third boot shows the compacted journal still replays
    let (addr, h) = start_server(&journal);
    let (_, listing) = request(&addr, "GET", "/jobs", None).unwrap();
    assert_eq!(listing.get("jobs").as_arr().unwrap().len(), 2);
    shutdown(&addr, h);

    std::fs::remove_file(&journal).ok();
    std::fs::remove_file(&ckpt).ok();
}
