//! End-to-end test of the `serve` subsystem: boot a real server on an
//! ephemeral port, drive it purely over the HTTP/JSON protocol — submit
//! FP32 + INT8 jobs against the synthetic datasets, poll them to Done,
//! cancel one mid-run, and exercise queue-full backpressure.

use elasticzo::serve::{request, ServeOptions, Server};
use elasticzo::util::json::Value;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

fn start_server(workers: usize, queue_cap: usize) -> (String, JoinHandle<()>) {
    let server =
        Server::bind(&ServeOptions { port: 0, workers, queue_cap, ..Default::default() }).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || server.run().unwrap());
    (addr, h)
}

fn shutdown(addr: &str, handle: JoinHandle<()>) {
    let (status, _) = request(addr, "POST", "/shutdown", None).unwrap();
    assert_eq!(status, 200);
    handle.join().unwrap();
}

fn submit(addr: &str, spec: &str) -> u64 {
    let body = elasticzo::util::json::parse(spec).unwrap();
    let (status, v) = request(addr, "POST", "/jobs", Some(&body)).unwrap();
    assert_eq!(status, 200, "submit failed: {}", elasticzo::util::json::to_string(&v));
    v.get("id").as_f64().unwrap() as u64
}

fn get_job(addr: &str, id: u64) -> Value {
    let (status, v) = request(addr, "GET", &format!("/jobs/{id}"), None).unwrap();
    assert_eq!(status, 200);
    v
}

fn state_of(v: &Value) -> String {
    v.get("state").as_str().unwrap_or("?").to_string()
}

fn poll_until(
    addr: &str,
    id: u64,
    pred: impl Fn(&Value) -> bool,
    what: &str,
    timeout: Duration,
) -> Value {
    let t0 = Instant::now();
    loop {
        let v = get_job(addr, id);
        if pred(&v) {
            return v;
        }
        assert!(
            t0.elapsed() < timeout,
            "timed out waiting for {what} on job {id}; last state: {}",
            elasticzo::util::json::to_string(&v)
        );
        std::thread::sleep(Duration::from_millis(30));
    }
}

fn poll_terminal(addr: &str, id: u64, timeout: Duration) -> Value {
    poll_until(
        addr,
        id,
        |v| matches!(state_of(v).as_str(), "done" | "failed" | "cancelled"),
        "a terminal state",
        timeout,
    )
}

const LONG: Duration = Duration::from_secs(300);

#[test]
fn concurrent_fp32_and_int8_jobs_reach_done() {
    let (addr, h) = start_server(2, 8);

    // health + empty listing first
    let (status, v) = request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(v.get("ok").as_bool(), Some(true));
    let (_, v) = request(&addr, "GET", "/jobs", None).unwrap();
    assert_eq!(v.get("jobs").as_arr().unwrap().len(), 0);

    // one FP32 cls1 job + one INT8 job, running concurrently on 2 workers
    let fp32 = submit(
        &addr,
        r#"{"name": "fp32-cls1", "model": "lenet", "dataset": "mnist",
            "method": "cls1", "precision": "fp32", "engine": "native",
            "epochs": 2, "batch": 16, "train_n": 192, "test_n": 96, "seed": 7}"#,
    );
    let int8 = submit(
        &addr,
        r#"{"name": "int8-cls1", "dataset": "mnist", "method": "cls1",
            "precision": "int8", "epochs": 2, "batch": 16,
            "train_n": 192, "test_n": 96, "seed": 8}"#,
    );
    assert_ne!(fp32, int8);

    let vf = poll_terminal(&addr, fp32, LONG);
    let vi = poll_terminal(&addr, int8, LONG);
    assert_eq!(state_of(&vf), "done", "{}", elasticzo::util::json::to_string(&vf));
    assert_eq!(state_of(&vi), "done", "{}", elasticzo::util::json::to_string(&vi));
    for (v, label) in [(&vf, "fp32"), (&vi, "int8")] {
        assert!(
            v.get("best_test_acc").as_f64().unwrap() > 0.0,
            "{label} job must reach nonzero accuracy"
        );
        assert_eq!(v.get("history").as_arr().unwrap().len(), 2, "{label} history");
    }

    // aggregate stats reflect the runs
    let (_, s) = request(&addr, "GET", "/stats", None).unwrap();
    assert_eq!(s.get("jobs_done").as_usize(), Some(2));
    assert_eq!(s.get("epochs_total").as_usize(), Some(4));
    assert!(s.get("epochs_per_sec").as_f64().unwrap() > 0.0);

    shutdown(&addr, h);
}

#[test]
fn cancellation_stops_a_running_job() {
    let (addr, h) = start_server(1, 8);
    // far more epochs than can finish; cancelled as soon as it reports
    // its first epoch
    let id = submit(
        &addr,
        r#"{"method": "full-zo", "precision": "fp32", "engine": "native",
            "epochs": 10000, "batch": 16, "train_n": 64, "test_n": 32}"#,
    );
    poll_until(
        &addr,
        id,
        |v| v.get("epochs_done").as_usize().unwrap_or(0) >= 1,
        "first epoch",
        LONG,
    );
    let (status, v) = request(&addr, "POST", &format!("/jobs/{id}/cancel"), None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(v.get("action").as_str(), Some("stop-requested"));

    let v = poll_terminal(&addr, id, LONG);
    assert_eq!(state_of(&v), "cancelled");
    let epochs_done = v.get("epochs_done").as_usize().unwrap();
    assert!(epochs_done < 10000, "must stop early, ran {epochs_done} epochs");

    // cancelling again reports already-terminal; unknown ids 404
    let (_, v) = request(&addr, "POST", &format!("/jobs/{id}/cancel"), None).unwrap();
    assert_eq!(v.get("action").as_str(), Some("already-terminal"));
    let (status, _) = request(&addr, "POST", "/jobs/99999/cancel", None).unwrap();
    assert_eq!(status, 404);

    shutdown(&addr, h);
}

#[test]
fn queue_full_returns_structured_429() {
    // 1 worker, queue capacity 1: one running + one queued fills the
    // server; the third submission must be rejected with backpressure.
    let (addr, h) = start_server(1, 1);
    let long_job = r#"{"method": "full-zo", "precision": "fp32", "engine": "native",
                       "epochs": 10000, "batch": 16, "train_n": 64, "test_n": 32}"#;

    let a = submit(&addr, long_job);
    // wait until the worker picked job A up, so B deterministically
    // occupies the single queue slot
    poll_until(&addr, a, |v| state_of(v) == "running", "job A running", LONG);
    let b = submit(&addr, long_job);

    let body = elasticzo::util::json::parse(long_job).unwrap();
    let (status, v) = request(&addr, "POST", "/jobs", Some(&body)).unwrap();
    assert_eq!(status, 429, "expected backpressure, got {status}");
    assert_eq!(v.get("error").as_str(), Some("queue full"));
    assert_eq!(v.get("capacity").as_usize(), Some(1));

    // the rejected job never shows up in the listing
    let (_, listing) = request(&addr, "GET", "/jobs", None).unwrap();
    assert_eq!(listing.get("jobs").as_arr().unwrap().len(), 2);

    // malformed and invalid submissions are 400s with structured errors
    let bad = elasticzo::util::json::parse(r#"{"model": "resnet"}"#).unwrap();
    let (status, v) = request(&addr, "POST", "/jobs", Some(&bad)).unwrap();
    assert_eq!(status, 400);
    assert!(v.get("error").as_str().unwrap().contains("invalid job spec"));

    // unblock the workers so shutdown joins quickly
    for id in [a, b] {
        let (status, _) =
            request(&addr, "POST", &format!("/jobs/{id}/cancel"), None).unwrap();
        assert_eq!(status, 200);
    }
    poll_terminal(&addr, a, LONG);
    shutdown(&addr, h);
}
