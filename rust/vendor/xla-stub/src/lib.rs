//! No-op stand-in for the external `xla` (xla-rs) bindings.
//!
//! The `elasticzo` crate's `xla` feature compiles the PJRT execution
//! path (`runtime::{executable,registry}` + `coordinator::xla_engine`)
//! against the xla-rs API. The real bindings link a PJRT plugin and
//! cannot be vendored here, so this crate mirrors exactly the API
//! surface those modules use — same names, same shapes — with every
//! runtime entry point returning [`Error`]. `cargo check --features
//! xla` (and the full test suite) therefore builds everywhere; actually
//! executing AOT artifacts requires substituting the real crate by
//! retargeting the path dependency in `rust/Cargo.toml`:
//!
//! ```toml
//! [dependencies]
//! xla = { path = "/path/to/xla-rs", optional = true }
//! ```
//!
//! (or by overwriting `rust/vendor/xla-stub` with a real checkout —
//! Cargo `[patch]` entries only override registry/git sources, never a
//! path dependency, so editing the path is the supported swap).
//!
//! The `elasticzo` side degrades gracefully either way: the engine
//! builder catches the open error and falls back to the native engine
//! with a warning (see `exp::build_engine_at`).

use std::fmt;

/// The single error every stubbed entry point returns.
#[derive(Debug)]
pub struct Error(&'static str);

impl Error {
    fn stub() -> Error {
        Error(
            "built against the in-tree no-op `xla` stub (rust/vendor/xla-stub); \
             retarget the `xla` path dependency in rust/Cargo.toml at the real \
             xla-rs bindings to execute artifacts",
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes of the artifact ABI (the subset the manifest knows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S8,
    S32,
}

/// Host-side tensor value (always empty in the stub).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(Error::stub())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::stub())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub())
    }
}

/// Parsed HLO module (never constructible at runtime in the stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub())
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub())
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub())
    }
}

/// A compiled executable bound to a client.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_errors_with_the_stub_message() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0; 8])
            .is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("no-op `xla` stub"), "{msg}");
    }
}
