//! NITI INT8 engine benches vs the FP32 native engine — the substrate
//! of the paper's Fig. 7 "INT8 is 1.38–1.42× faster" claim, plus the
//! rounding primitives.

use elasticzo::coordinator::{Engine, Model, ParamSet};
use elasticzo::coordinator::native_engine::NativeEngine;
use elasticzo::data;
use elasticzo::int8::{lenet8, rounding};
use elasticzo::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new();

    let d = data::synth_mnist::generate(32, 1);
    let mut y = vec![0.0f32; 32 * 10];
    for (i, &l) in d.labels.iter().enumerate() {
        y[i * 10 + l as usize] = 1.0;
    }

    // FP32 native forward
    let params = ParamSet::init(Model::LeNet, 1);
    let mut native = NativeEngine::new(Model::LeNet);
    let fp32 = b
        .bench("forward_b32/native_fp32", || {
            native.forward(&params, &d.x, &y, 32).unwrap().loss
        })
        .cloned();

    // INT8 NITI forward
    let ws = lenet8::init_params(2, 32);
    let xq = lenet8::quantize_input(&d.x, 32);
    let int8 = b
        .bench("forward_b32/native_int8", || {
            lenet8::forward(&ws, &xq, 32).logits.exp
        })
        .cloned();

    if let (Some(f), Some(i)) = (fp32, int8) {
        b.report_metric(
            "fp32 / int8 forward ratio (paper: 1.38-1.42x)",
            f.mean.as_secs_f64() / i.mean.as_secs_f64(),
            "x",
        );
    }

    // INT8 backward (tail + full)
    let fwd = lenet8::forward(&ws, &xq, 32);
    let mut ws_mut = ws.clone();
    b.bench("tail_update_c1_b32/int8", || {
        lenet8::tail_update(&mut ws_mut, &fwd, &d.labels, 1, 32, 5);
    });
    let mut ws_mut2 = ws.clone();
    b.bench("full_update_b32/int8", || {
        lenet8::full_update(&mut ws_mut2, &fwd, &d.labels, 32, 5);
    });

    // rounding primitives (per-element costs)
    let vals: Vec<i32> = (0..4096).map(|i| (i * 7919) as i32 - 16_000_000).collect();
    b.bench("rshift_round/4096", || {
        vals.iter().map(|&v| rounding::rshift_round(v, 9)).sum::<i32>()
    });
    b.bench("pseudo_stochastic_round/4096", || {
        vals.iter()
            .map(|&v| rounding::pseudo_stochastic_round(v, 9))
            .sum::<i32>()
    });
}
