//! Forward-pass latency: XLA fast artifact vs XLA Pallas-interpret
//! artifact vs native engine, LeNet and PointNet. The XLA-fast/native
//! comparison is the §Perf L2 result; the Pallas variant documents why
//! interpret mode is compile-target-only on CPU.

use elasticzo::coordinator::native_engine::NativeEngine;
#[cfg(feature = "xla")]
use elasticzo::coordinator::xla_engine::XlaEngine;
use elasticzo::coordinator::{Engine, Model, ParamSet};
use elasticzo::data;
use elasticzo::util::bench::Bencher;

fn batch(bsz: usize) -> (Vec<f32>, Vec<f32>) {
    let d = data::synth_mnist::generate(bsz, 1);
    let mut y = vec![0.0f32; bsz * 10];
    for (i, &l) in d.labels.iter().enumerate() {
        y[i * 10 + l as usize] = 1.0;
    }
    (d.x, y)
}

fn main() {
    let mut b = Bencher::new();
    let params = ParamSet::init(Model::LeNet, 1);
    let (x, y) = batch(32);

    // native engine
    let mut native = NativeEngine::new(Model::LeNet);
    b.bench("lenet_fwd_b32/native", || {
        native.forward(&params, &x, &y, 32).unwrap().loss
    });

    // XLA fast artifact
    #[cfg(feature = "xla")]
    match XlaEngine::open_default(Model::LeNet, 32) {
        Ok(mut xla) => {
            b.bench("lenet_fwd_b32/xla_fast", || {
                xla.forward(&params, &x, &y, 32).unwrap().loss
            });
        }
        Err(e) => eprintln!("skipping xla fast bench: {e:#}"),
    }

    // XLA Pallas-interpret artifact (compile-target path; slow on CPU)
    #[cfg(feature = "xla")]
    {
        std::env::set_var("REPRO_PALLAS_FWD", "1");
        match XlaEngine::open_default(Model::LeNet, 32) {
            Ok(mut xla) => {
                b.bench("lenet_fwd_b32/xla_pallas_interp", || {
                    xla.forward(&params, &x, &y, 32).unwrap().loss
                });
            }
            Err(e) => eprintln!("skipping xla pallas bench: {e:#}"),
        }
        std::env::remove_var("REPRO_PALLAS_FWD");
    }

    // PointNet
    let model = Model::PointNet { npoints: 128, ncls: 40 };
    let pn_params = ParamSet::init(model, 2);
    let d = data::synth_modelnet::generate(16, 128, 3);
    let mut yy = vec![0.0f32; 16 * 40];
    for (i, &l) in d.labels.iter().enumerate() {
        yy[i * 40 + l as usize] = 1.0;
    }
    let mut native_pn = NativeEngine::new(model);
    b.bench("pointnet_fwd_n128_b16/native", || {
        native_pn.forward(&pn_params, &d.x, &yy, 16).unwrap().loss
    });
    #[cfg(feature = "xla")]
    if let Ok(mut xla) = XlaEngine::open_default(model, 16) {
        b.bench("pointnet_fwd_n128_b16/xla_fast", || {
            xla.forward(&pn_params, &d.x, &yy, 16).unwrap().loss
        });
    }

    // derived headline: xla_fast speedup over pallas-interpret
    let find = |name: &str| b.results.iter().find(|s| s.name.contains(name)).cloned();
    if let (Some(fast), Some(pallas)) = (find("xla_fast"), find("pallas_interp")) {
        b.report_metric(
            "pallas_interp / xla_fast latency ratio",
            pallas.mean.as_secs_f64() / fast.mean.as_secs_f64(),
            "x",
        );
    }
}
