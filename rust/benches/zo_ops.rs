//! ZO-engine micro-benches: the seed-trick perturb/update passes over
//! LeNet (108k params) and PointNet (816k params) — the paper Fig. 7
//! "ZO Perturb"/"ZO Update" slices — plus the int8 sparse perturbation
//! and the integer CE sign (paper Eq. 7–12). Default rows run the
//! chunked kernel path (`coordinator::kernels`); `*_scalar` rows keep
//! the fused one-element-at-a-time reference for comparison.

use elasticzo::coordinator::int8_trainer::{perturb_int8, zo_update_int8};
use elasticzo::coordinator::{kernels, zo, Model, ParamSet};
use elasticzo::int8::{intce, lenet8};
use elasticzo::rng::Rng64;
use elasticzo::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new();

    // FP32 perturbation over both model sizes. Kernel rows bump the
    // step every call so each iteration pays a fresh `z` fill —
    // comparable work to the scalar rows.
    let mut lenet = ParamSet::init(Model::LeNet, 1);
    let nt = lenet.num_tensors();
    let lenet_elems: usize = lenet.data.iter().map(|t| t.len()).sum();
    let mut kz = kernels::StepZ::new();
    let mut kstep = 0u64;
    b.bench("zo_perturb/lenet_107k", || {
        kstep += 1;
        kz.prepare(7, kstep, lenet_elems, None);
        kernels::apply_z(&mut lenet, nt, 1e-3, kz.z());
    });
    b.bench("zo_perturb_scalar/lenet_107k", || {
        zo::perturb(&mut lenet, nt, 7, 1, 1e-3);
    });
    let mut pn = ParamSet::init(Model::PointNet { npoints: 128, ncls: 40 }, 2);
    let nt_pn = pn.num_tensors();
    let pn_elems: usize = pn.data.iter().map(|t| t.len()).sum();
    let mut kz_pn = kernels::StepZ::new();
    let mut kstep_pn = 0u64;
    b.bench("zo_perturb/pointnet_816k", || {
        kstep_pn += 1;
        kz_pn.prepare(7, kstep_pn, pn_elems, None);
        kernels::apply_z(&mut pn, nt_pn, 1e-3, kz_pn.z());
    });
    b.bench("zo_perturb_scalar/pointnet_816k", || {
        zo::perturb(&mut pn, nt_pn, 7, 1, 1e-3);
    });

    if let Some(s) = b.results.iter().find(|s| s.name == "zo_perturb/pointnet_816k") {
        b.report_metric(
            "pointnet perturb throughput",
            816_424.0 / s.mean.as_secs_f64() / 1e6,
            "Mparams/s",
        );
    }

    // INT8 sparse perturbation + update (Alg. 2). The kernel update
    // replays the step's cached `z` — the product path, where the
    // perturb legs already paid for the fill.
    let mut ws = lenet8::init_params(3, 32);
    let zo8_elems: usize = ws[..5].iter().map(|w| w.numel()).sum();
    let mut kz8 = kernels::StepZi8::new();
    let mut kstep8 = 0u64;
    b.bench("int8_perturb/lenet_107k", || {
        kstep8 += 1;
        kz8.prepare(7, kstep8, zo8_elems, 15, 0.5);
        kernels::apply_z_i8(&mut ws, 5, 1, kz8.z());
    });
    b.bench("int8_perturb_scalar/lenet_107k", || {
        perturb_int8(&mut ws, 5, 7, 1, 1, 15, 0.5);
    });
    let (mut acc, mut upd) = (Vec::new(), Vec::new());
    b.bench("int8_zo_update/lenet_107k", || {
        kernels::zo_update_z_i8(&mut ws, 5, 1, 1, kz8.z(), &mut acc, &mut upd);
    });
    b.bench("int8_zo_update_scalar/lenet_107k", || {
        zo_update_int8(&mut ws, 5, 7, 1, 1, 1, 15, 0.5);
    });

    // integer CE sign vs float CE sign (per ZO step, B=32)
    let mut rng = Rng64::new(5);
    let alpha: Vec<i8> = (0..32 * 10).map(|_| rng.uniform_i32(-127, 127) as i8).collect();
    let beta: Vec<i8> = alpha
        .iter()
        .map(|&v| (v as i32 + rng.uniform_i32(-10, 10)).clamp(-127, 127) as i8)
        .collect();
    let labels: Vec<u8> = (0..32).map(|_| (rng.next_u64() % 10) as u8).collect();
    b.bench("intce_sign/b32", || {
        intce::loss_diff_sign_int(&alpha, -3, &beta, -3, &labels, 32, 10)
    });
    b.bench("float_ce_sign/b32", || {
        intce::loss_diff_f32(&alpha, -3, &beta, -3, &labels, 32, 10).signum()
    });
}
