//! Job-server throughput: jobs/sec for a batch of tiny training jobs at
//! worker-pool sizes 1 / 2 / 4, over the real HTTP + queue + registry
//! stack. The headline metric is the 4-worker : 1-worker speedup —
//! >1.5x demonstrates that `repro serve` genuinely overlaps jobs.

use elasticzo::serve::{request, ServeOptions, Server};
use elasticzo::util::bench::Bencher;
use elasticzo::util::json;
use std::time::{Duration, Instant};

const JOBS: usize = 12;

/// Tiny but real job: 1 epoch of FP32 Cls1 LeNet on 64 synthetic
/// samples (4 ZO steps of 2 forwards each + eval).
fn tiny_spec(seed: usize) -> String {
    format!(
        r#"{{"method": "cls1", "precision": "fp32", "engine": "native",
            "epochs": 1, "batch": 16, "train_n": 64, "test_n": 32, "seed": {seed}}}"#
    )
}

/// Boot a server with `workers` workers, push JOBS jobs through it, and
/// return the jobs/sec of the drain.
fn run_fleet(workers: usize) -> f64 {
    let server = Server::bind(&ServeOptions {
        port: 0,
        workers,
        queue_cap: JOBS + 4,
        ..Default::default()
    })
    .expect("bind server");
    let addr = server.local_addr().expect("addr").to_string();
    let handle = std::thread::spawn(move || server.run().expect("server run"));

    let t0 = Instant::now();
    for i in 0..JOBS {
        let body = json::parse(&tiny_spec(i)).unwrap();
        let (status, v) = request(&addr, "POST", "/jobs", Some(&body)).expect("submit");
        assert_eq!(status, 200, "submit: {}", json::to_string(&v));
    }
    // drain: poll aggregate stats until every job is done
    loop {
        let (_, s) = request(&addr, "GET", "/stats", None).expect("stats");
        let done = s.get("jobs_done").as_usize().unwrap_or(0);
        let failed = s.get("jobs_failed").as_usize().unwrap_or(0);
        assert_eq!(failed, 0, "jobs failed during bench");
        if done == JOBS {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let secs = t0.elapsed().as_secs_f64();

    let (status, _) = request(&addr, "POST", "/shutdown", None).expect("shutdown");
    assert_eq!(status, 200);
    handle.join().expect("server thread");
    JOBS as f64 / secs
}

fn main() {
    let b = Bencher::new();
    let mut rates = Vec::new();
    for workers in [1usize, 2, 4] {
        let rate = run_fleet(workers);
        b.report_metric(&format!("serve_throughput/workers_{workers}"), rate, "jobs/sec");
        rates.push((workers, rate));
    }
    let rate_of = |w: usize| rates.iter().find(|(n, _)| *n == w).map(|(_, r)| *r);
    if let (Some(r1), Some(r4)) = (rate_of(1), rate_of(4)) {
        b.report_metric("serve_throughput 4-worker : 1-worker speedup", r4 / r1, "x");
    }
}
