//! Job-server throughput: jobs/sec for a batch of tiny training jobs at
//! worker-pool sizes 1 / 2 / 4, over the real HTTP + queue + registry
//! stack — the 4-worker : 1-worker speedup shows `repro serve`
//! genuinely overlaps jobs. Plus the connection plane itself:
//! requests/sec over one keep-alive socket vs one connection per
//! request, and SSE fan-out (hundreds of concurrent firehose streams,
//! where the pre-reactor server hard-refused anything past 64).

use elasticzo::serve::{request, ServeOptions, Server};
use elasticzo::util::bench::Bencher;
use elasticzo::util::json;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

const JOBS: usize = 12;

/// Tiny but real job: 1 epoch of FP32 Cls1 LeNet on 64 synthetic
/// samples (4 ZO steps of 2 forwards each + eval).
fn tiny_spec(seed: usize) -> String {
    format!(
        r#"{{"method": "cls1", "precision": "fp32", "engine": "native",
            "epochs": 1, "batch": 16, "train_n": 64, "test_n": 32, "seed": {seed}}}"#
    )
}

/// Boot a server with `workers` workers, push JOBS jobs through it, and
/// return the jobs/sec of the drain.
fn run_fleet(workers: usize) -> f64 {
    let server = Server::bind(&ServeOptions {
        port: 0,
        workers,
        queue_cap: JOBS + 4,
        ..Default::default()
    })
    .expect("bind server");
    let addr = server.local_addr().expect("addr").to_string();
    let handle = std::thread::spawn(move || server.run().expect("server run"));

    let t0 = Instant::now();
    for i in 0..JOBS {
        let body = json::parse(&tiny_spec(i)).unwrap();
        let (status, v) = request(&addr, "POST", "/jobs", Some(&body)).expect("submit");
        assert_eq!(status, 200, "submit: {}", json::to_string(&v));
    }
    // drain: poll aggregate stats until every job is done
    loop {
        let (_, s) = request(&addr, "GET", "/stats", None).expect("stats");
        let done = s.get("jobs_done").as_usize().unwrap_or(0);
        let failed = s.get("jobs_failed").as_usize().unwrap_or(0);
        assert_eq!(failed, 0, "jobs failed during bench");
        if done == JOBS {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let secs = t0.elapsed().as_secs_f64();

    let (status, _) = request(&addr, "POST", "/shutdown", None).expect("shutdown");
    assert_eq!(status, 200);
    handle.join().expect("server thread");
    JOBS as f64 / secs
}

/// Requests/sec for `GET /healthz`: `keep_alive` reuses one socket for
/// every request; otherwise each request pays connect + teardown (the
/// old thread-per-connection shape).
fn run_rps(keep_alive: bool, reqs: usize) -> f64 {
    let server = Server::bind(&ServeOptions { port: 0, workers: 1, queue_cap: 4, ..Default::default() })
        .expect("bind server");
    let addr = server.local_addr().expect("addr");
    let handle = std::thread::spawn(move || server.run().expect("server run"));
    let find = |h: &[u8], n: &[u8]| h.windows(n.len()).position(|w| w == n);
    let t0 = Instant::now();
    if keep_alive {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.set_nodelay(true).expect("nodelay");
        let mut buf: Vec<u8> = Vec::new();
        let mut tmp = [0u8; 4096];
        for _ in 0..reqs {
            s.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").expect("write");
            loop {
                if let Some(he) = find(&buf, b"\r\n\r\n") {
                    let head = std::str::from_utf8(&buf[..he]).expect("utf8 head");
                    let clen: usize = head
                        .lines()
                        .find_map(|l| {
                            let (k, v) = l.split_once(':')?;
                            k.trim()
                                .eq_ignore_ascii_case("content-length")
                                .then(|| v.trim().parse().ok())?
                        })
                        .unwrap_or(0);
                    if buf.len() >= he + 4 + clen {
                        buf.drain(..he + 4 + clen);
                        break;
                    }
                }
                let n = s.read(&mut tmp).expect("read");
                assert!(n > 0, "server closed keep-alive connection");
                buf.extend_from_slice(&tmp[..n]);
            }
        }
    } else {
        for _ in 0..reqs {
            let mut s = TcpStream::connect(addr).expect("connect");
            s.write_all(b"GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n").expect("write");
            let mut raw = Vec::new();
            s.read_to_end(&mut raw).expect("read");
            assert!(!raw.is_empty(), "empty response");
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    request(&addr.to_string(), "POST", "/shutdown", None).expect("shutdown");
    handle.join().expect("server thread");
    reqs as f64 / secs
}

/// Streams/sec to open `streams` concurrent firehose subscribers, each
/// confirmed live by its SSE response header.
fn run_fanout(streams: usize) -> f64 {
    let server = Server::bind(&ServeOptions { port: 0, workers: 1, queue_cap: 4, ..Default::default() })
        .expect("bind server");
    let addr = server.local_addr().expect("addr");
    let handle = std::thread::spawn(move || server.run().expect("server run"));
    let t0 = Instant::now();
    let mut conns = Vec::with_capacity(streams);
    for _ in 0..streams {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(10))).expect("timeout");
        s.write_all(b"GET /events HTTP/1.1\r\nConnection: close\r\n\r\n").expect("write");
        conns.push(s);
    }
    for s in &mut conns {
        let mut got: Vec<u8> = Vec::new();
        let mut tmp = [0u8; 1024];
        while !got.windows(4).any(|w| w == b"\r\n\r\n") {
            let n = s.read(&mut tmp).expect("read header");
            assert!(n > 0, "stream closed before the SSE header");
            got.extend_from_slice(&tmp[..n]);
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    drop(conns);
    request(&addr.to_string(), "POST", "/shutdown", None).expect("shutdown");
    handle.join().expect("server thread");
    streams as f64 / secs
}

fn main() {
    let b = Bencher::new();
    let mut rates = Vec::new();
    for workers in [1usize, 2, 4] {
        let rate = run_fleet(workers);
        b.report_metric(&format!("serve_throughput/workers_{workers}"), rate, "jobs/sec");
        rates.push((workers, rate));
    }
    let rate_of = |w: usize| rates.iter().find(|(n, _)| *n == w).map(|(_, r)| *r);
    if let (Some(r1), Some(r4)) = (rate_of(1), rate_of(4)) {
        b.report_metric("serve_throughput 4-worker : 1-worker speedup", r4 / r1, "x");
    }

    let reqs = 500;
    let rps_ka = run_rps(true, reqs);
    let rps_close = run_rps(false, reqs);
    b.report_metric("serve_rps/keepalive", rps_ka, "req/sec");
    b.report_metric("serve_rps/close", rps_close, "req/sec");
    b.report_metric("serve_rps keep-alive : close speedup", rps_ka / rps_close, "x");

    let streams = 256;
    let fanout = run_fanout(streams);
    b.report_metric(&format!("serve_rps/sse_fanout_{streams}"), fanout, "streams/sec");
}
