//! End-to-end training-step benches — one per paper-table workload:
//! the full ZO / ElasticZO / BP step (2 forwards + update [+ tail BP])
//! on both engines, FP32 and INT8. These are the rows behind the
//! Fig. 7 epoch-time claims and the §Perf L3 numbers.

use elasticzo::coordinator::native_engine::NativeEngine;
use elasticzo::coordinator::trainer::zo_step;
use elasticzo::coordinator::TrainSpec;
#[cfg(feature = "xla")]
use elasticzo::coordinator::xla_engine::XlaEngine;
use elasticzo::coordinator::{Engine, Method, Model, ParamSet};
use elasticzo::data;
use elasticzo::data::loader::Batch;
use elasticzo::int8::lenet8;
use elasticzo::telemetry::PhaseTimer;
use elasticzo::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new();
    let d = data::synth_mnist::generate(32, 1);
    let mut y = vec![0.0f32; 32 * 10];
    for (i, &l) in d.labels.iter().enumerate() {
        y[i * 10 + l as usize] = 1.0;
    }
    let batch = Batch { x: d.x.clone(), y_onehot: y.clone(), labels: d.labels.clone(), bsz: 32 };

    let spec_for = |method: Method| TrainSpec {
        method,
        epochs: 1,
        batch: 32,
        lr0: 1e-3,
        eps: 1e-2,
        g_clip: 5.0,
        seed: 9,
        eval_every: 1,
        verbose: false,
        ..Default::default()
    };

    // FP32 steps on both engines
    for method in [Method::FullZo, Method::Cls1, Method::Cls2] {
        let spec = spec_for(method);

        let mut native = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 3);
        let mut timer = PhaseTimer::new();
        let mut step = 0u64;
        b.bench(&format!("step_{}/native", spec.method.label().replace(' ', "_")), || {
            step += 1;
            zo_step(&mut native, &mut params, &batch, step, 1e-3, &spec, &mut timer).unwrap()
        });

        #[cfg(feature = "xla")]
        if let Ok(mut xla) = XlaEngine::open_default(Model::LeNet, 32) {
            let mut params = ParamSet::init(Model::LeNet, 3);
            let mut timer = PhaseTimer::new();
            let mut step = 0u64;
            b.bench(&format!("step_{}/xla", spec.method.label().replace(' ', "_")), || {
                step += 1;
                zo_step(&mut xla, &mut params, &batch, step, 1e-3, &spec, &mut timer).unwrap()
            });
        }
    }

    // Full BP step
    let mut native = NativeEngine::new(Model::LeNet);
    let mut params = ParamSet::init(Model::LeNet, 4);
    b.bench("step_Full_BP/native", || {
        native.full_step(&mut params, &d.x, &y, 32, 0.01).unwrap().loss
    });
    #[cfg(feature = "xla")]
    if let Ok(mut xla) = XlaEngine::open_default(Model::LeNet, 32) {
        let mut params = ParamSet::init(Model::LeNet, 4);
        b.bench("step_Full_BP/xla", || {
            xla.full_step(&mut params, &d.x, &y, 32, 0.01).unwrap().loss
        });
    }

    // INT8 step (one minibatch of the int8 session step, Cls1)
    let mut ws = lenet8::init_params(5, 32);
    let xq = lenet8::quantize_input(&d.x, 32);
    let (seed, r_max) = (1u64, 15i8);
    let mut step = 0u64;
    b.bench("step_Cls1/int8_native", || {
        use elasticzo::coordinator::int8_trainer::{perturb_int8, zo_update_int8};
        use elasticzo::int8::intce;
        step += 1;
        perturb_int8(&mut ws, 4, seed, step, 1, r_max, 0.5);
        let fp = lenet8::forward(&ws, &xq, 32);
        perturb_int8(&mut ws, 4, seed, step, -2, r_max, 0.5);
        let fm = lenet8::forward(&ws, &xq, 32);
        let g = intce::loss_diff_sign_int(
            &fp.logits.data, fp.logits.exp, &fm.logits.data, fm.logits.exp,
            &d.labels, 32, 10,
        );
        perturb_int8(&mut ws, 4, seed, step, 1, r_max, 0.5);
        zo_update_int8(&mut ws, 4, seed, step, g, 1, r_max, 0.5);
        lenet8::tail_update(&mut ws, &fm, &d.labels, 1, 32, 5);
        g
    });
}
