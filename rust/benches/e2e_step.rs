//! End-to-end training-step benches — one per paper-table workload:
//! the full ZO / ElasticZO / BP step (2 forwards + update [+ tail BP])
//! on both engines, FP32 and INT8. These are the rows behind the
//! Fig. 7 epoch-time claims and the §Perf L3 numbers. Default ZO rows
//! run the kernel path (`Fp32Session`: per-step cached `z`, parallel
//! ±ε pair); `*_scalar` rows time [`zo_step`], the scalar reference
//! the parity suite pins the kernels to.

use elasticzo::coordinator::native_engine::NativeEngine;
use elasticzo::coordinator::trainer::zo_step;
use elasticzo::coordinator::TrainSpec;
#[cfg(feature = "xla")]
use elasticzo::coordinator::xla_engine::XlaEngine;
use elasticzo::coordinator::{kernels, Engine, Fp32Session, Method, Model, ParamSet, TrainSession};
use elasticzo::data;
use elasticzo::data::loader::Batch;
use elasticzo::int8::lenet8;
use elasticzo::telemetry::PhaseTimer;
use elasticzo::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new();
    let d = data::synth_mnist::generate(32, 1);
    let mut y = vec![0.0f32; 32 * 10];
    for (i, &l) in d.labels.iter().enumerate() {
        y[i * 10 + l as usize] = 1.0;
    }
    let batch = Batch { x: d.x.clone(), y_onehot: y.clone(), labels: d.labels.clone(), bsz: 32 };

    let spec_for = |method: Method| TrainSpec {
        method,
        epochs: 1,
        batch: 32,
        lr0: 1e-3,
        eps: 1e-2,
        g_clip: 5.0,
        seed: 9,
        eval_every: 1,
        verbose: false,
        ..Default::default()
    };

    // FP32 steps on both engines
    for method in [Method::FULL_ZO, Method::CLS1, Method::CLS2] {
        let spec = spec_for(method);
        let tag = spec.method.label().replace(' ', "_");

        let mut native = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 3);
        let mut sess = Fp32Session::new(&mut native, &mut params, &spec).unwrap();
        let mut timer = PhaseTimer::new();
        let mut step = 0u64;
        b.bench(&format!("step_{tag}/native"), || {
            step += 1;
            sess.step(&batch, step, &mut timer).unwrap().loss
        });
        drop(sess);

        let mut native = NativeEngine::new(Model::LeNet);
        let mut params = ParamSet::init(Model::LeNet, 3);
        let mut timer = PhaseTimer::new();
        let mut step = 0u64;
        b.bench(&format!("step_{tag}_scalar/native"), || {
            step += 1;
            zo_step(&mut native, &mut params, &batch, step, 1e-3, &spec, &mut timer).unwrap()
        });

        #[cfg(feature = "xla")]
        if let Ok(mut xla) = XlaEngine::open_default(Model::LeNet, 32) {
            let mut params = ParamSet::init(Model::LeNet, 3);
            let mut timer = PhaseTimer::new();
            let mut step = 0u64;
            b.bench(&format!("step_{tag}/xla"), || {
                step += 1;
                zo_step(&mut xla, &mut params, &batch, step, 1e-3, &spec, &mut timer).unwrap()
            });
        }
    }

    // Full BP step
    let mut native = NativeEngine::new(Model::LeNet);
    let mut params = ParamSet::init(Model::LeNet, 4);
    b.bench("step_Full_BP/native", || {
        native.full_step(&mut params, &d.x, &y, 32, 0.01).unwrap().loss
    });
    #[cfg(feature = "xla")]
    if let Ok(mut xla) = XlaEngine::open_default(Model::LeNet, 32) {
        let mut params = ParamSet::init(Model::LeNet, 4);
        b.bench("step_Full_BP/xla", || {
            xla.full_step(&mut params, &d.x, &y, 32, 0.01).unwrap().loss
        });
    }

    // INT8 step (one minibatch of the int8 session step, Cls1) —
    // kernel path first (one `z` fill replayed by all four legs, ±ε
    // forwards side by side when a second core is up), then the
    // scalar reference.
    let mut ws = lenet8::init_params(5, 32);
    let xq = lenet8::quantize_input(&d.x, 32);
    let (seed, r_max) = (1u64, 15i8);
    let mut snap8 = ws.clone();
    let zo8: usize = ws[..4].iter().map(|w| w.numel()).sum();
    let mut kz8 = kernels::StepZi8::new();
    let (mut acc8, mut upd8) = (Vec::new(), Vec::new());
    let par8 = kernels::hw_threads() > 1;
    let mut step = 0u64;
    b.bench("step_Cls1/int8_native", || {
        use elasticzo::int8::intce;
        step += 1;
        kz8.prepare(seed, step, zo8, r_max, 0.5);
        kernels::apply_z_i8(&mut ws, 4, 1, kz8.z());
        let (fp, fm) = if par8 {
            snap8.clone_from(&ws);
            kernels::apply_z_i8(&mut ws, 4, -2, kz8.z());
            let (ws_ref, snap_ref, xq_ref) = (&ws, &snap8, &xq);
            std::thread::scope(|sc| {
                let h = sc.spawn(move || lenet8::forward(snap_ref, xq_ref, 32));
                let fm = lenet8::forward(ws_ref, xq_ref, 32);
                (h.join().expect("±ε int8 bench worker panicked"), fm)
            })
        } else {
            let fp = lenet8::forward(&ws, &xq, 32);
            kernels::apply_z_i8(&mut ws, 4, -2, kz8.z());
            (fp, lenet8::forward(&ws, &xq, 32))
        };
        let g = intce::loss_diff_sign_int(
            &fp.logits.data, fp.logits.exp, &fm.logits.data, fm.logits.exp,
            &d.labels, 32, 10,
        );
        kernels::apply_z_i8(&mut ws, 4, 1, kz8.z());
        kernels::zo_update_z_i8(&mut ws, 4, g, 1, kz8.z(), &mut acc8, &mut upd8);
        lenet8::tail_update(&mut ws, &fm, &d.labels, 1, 32, 5);
        g
    });
    let mut ws_s = lenet8::init_params(5, 32);
    let mut step_s = 0u64;
    b.bench("step_Cls1_scalar/int8_native", || {
        use elasticzo::coordinator::int8_trainer::{perturb_int8, zo_update_int8};
        use elasticzo::int8::intce;
        step_s += 1;
        perturb_int8(&mut ws_s, 4, seed, step_s, 1, r_max, 0.5);
        let fp = lenet8::forward(&ws_s, &xq, 32);
        perturb_int8(&mut ws_s, 4, seed, step_s, -2, r_max, 0.5);
        let fm = lenet8::forward(&ws_s, &xq, 32);
        let g = intce::loss_diff_sign_int(
            &fp.logits.data, fp.logits.exp, &fm.logits.data, fm.logits.exp,
            &d.labels, 32, 10,
        );
        perturb_int8(&mut ws_s, 4, seed, step_s, 1, r_max, 0.5);
        zo_update_int8(&mut ws_s, 4, seed, step_s, g, 1, r_max, 0.5);
        lenet8::tail_update(&mut ws_s, &fm, &d.labels, 1, 32, 5);
        g
    });
}
